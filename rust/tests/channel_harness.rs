//! The job-serving reactor, end to end without a single socket: the
//! channel harness (`dsc::coordinator::harness`) runs the identical
//! reactor + `JobQueue` + `RunMachine` stack over in-process site
//! sessions, with deterministic fault injection and a virtual clock.
//!
//! This suite owns the core job-server cases — concurrency parity,
//! central-offload pipelining, straggler deadlines, fault behavior, the
//! submit/pull policy gates. `rust/tests/job_server.rs` is the thin TCP
//! parity/smoke layer on top; `examples/tcp_cluster.rs` re-proves the
//! headline flow with separate OS processes. CI runs this file under
//! `DSC_THREADS=1` and `DSC_THREADS=4` (see `docs/TESTING.md`).

mod common;

use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use common::pull_global;
use dsc::config::PipelineConfig;
use dsc::coordinator::harness::{serve_channel, HarnessOpts};
use dsc::coordinator::server::{ServerOpts, ETA_UNKNOWN_NS};
use dsc::coordinator::{run_pipeline, spec_from_config};
use dsc::data::gmm;
use dsc::data::scenario::{self, Scenario, SitePart};
use dsc::data::Dataset;
use dsc::net::channel::Fault;
use dsc::net::{JobReport, JobSpec};
use dsc::spectral::Bandwidth;

fn workload() -> Vec<SitePart> {
    let ds = gmm::paper_mixture_10d(2_000, 0.1, 21);
    scenario::split(&ds, Scenario::D3, 2, 21)
}

fn datasets(parts: &[SitePart]) -> Vec<Dataset> {
    parts.iter().map(|p| p.data.clone()).collect()
}

fn cfg_with_seed(seed: u64) -> PipelineConfig {
    PipelineConfig {
        total_codes: 64,
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed,
        ..Default::default()
    }
}

/// One job's result as a client saw it: the leader's report plus the
/// pulled per-point labels assembled into the global vector
/// (`common::pull_global`).
struct ServedJob {
    report: JobReport,
    labels: Vec<u16>,
}

/// Push `specs` through a fresh channel harness (all submitted up front
/// when `concurrent`, else strictly one after another), pull every run's
/// labels, and join everything down cleanly.
fn serve_and_submit(
    parts: &[SitePart],
    specs: &[JobSpec],
    concurrent: bool,
) -> (Vec<ServedJob>, dsc::coordinator::server::ServerStats) {
    let cfg = cfg_with_seed(0);
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: if concurrent { specs.len().max(1) } else { 1 },
            queue_depth: 8,
            allow_label_pull: true,
            client_limit: Some(specs.len() as u64),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut harness = serve_channel(datasets(parts), &cfg, opts).unwrap();

    let mut served = Vec::new();
    if concurrent {
        // every job in flight before any result is awaited
        let clients: Vec<_> = specs.iter().map(|_| harness.client()).collect();
        let runs: Vec<u32> =
            clients.iter().zip(specs).map(|(c, s)| c.submit(s).unwrap()).collect();
        for (client, run) in clients.iter().zip(&runs) {
            let report = client.await_done(*run).unwrap();
            let labels = pull_global(client, *run, &report, parts);
            served.push(ServedJob { report, labels });
        }
        drop(clients); // disconnect: lets the server reach its client_limit
    } else {
        for spec in specs {
            let client = harness.client();
            let run = client.submit(spec).unwrap();
            let report = client.await_done(run).unwrap();
            let labels = pull_global(&client, run, &report, parts);
            served.push(ServedJob { report, labels });
        }
    }
    let (stats, outcomes) = harness.join().unwrap();
    // the server shutting down ends every site session cleanly
    for outcome in outcomes {
        assert_eq!(outcome.aborted_runs, 0);
    }
    (served, stats)
}

/// A two-phase gate for instrumenting one run's central step: the worker
/// announces it entered, then blocks until the test opens the gate.
struct Gate {
    entered: Mutex<bool>,
    entered_cv: Condvar,
    open: Mutex<bool>,
    open_cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate {
            entered: Mutex::new(false),
            entered_cv: Condvar::new(),
            open: Mutex::new(false),
            open_cv: Condvar::new(),
        })
    }

    /// Central-worker side: announce, then wait for the test.
    fn enter_and_wait(&self) {
        *self.entered.lock().unwrap() = true;
        self.entered_cv.notify_all();
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.open_cv.wait(open).unwrap();
        }
    }

    /// Test side: block until the worker is inside the central step.
    fn wait_entered(&self) {
        let mut entered = self.entered.lock().unwrap();
        while !*entered {
            entered = self.entered_cv.wait(entered).unwrap();
        }
    }

    /// Test side: release the worker.
    fn open(&self) {
        *self.open.lock().unwrap() = true;
        self.open_cv.notify_all();
    }
}

/// The concurrency acceptance core, socket-free: two jobs submitted
/// concurrently complete with labels and per-run, per-link byte counters
/// identical to running them sequentially — and identical labels to the
/// in-process channel pipeline. (`rust/tests/job_server.rs` extends this
/// parity across the TCP job server.)
#[test]
fn concurrent_jobs_match_sequential_and_pipeline() {
    let parts = workload();
    let spec_a = spec_from_config(&cfg_with_seed(21));
    let spec_b = spec_from_config(&cfg_with_seed(77));
    let specs = [spec_a, spec_b];

    let base_a = run_pipeline(&parts, &cfg_with_seed(21)).unwrap();
    let base_b = run_pipeline(&parts, &cfg_with_seed(77)).unwrap();

    let (concurrent, stats_c) = serve_and_submit(&parts, &specs, true);
    let (sequential, stats_s) = serve_and_submit(&parts, &specs, false);
    assert_eq!(stats_c.completed, 2);
    assert_eq!(stats_c.failed, 0);
    assert_eq!(stats_s.completed, 2);

    for (i, base) in [&base_a, &base_b].into_iter().enumerate() {
        // labels: concurrent == sequential == the channel pipeline
        assert_eq!(concurrent[i].labels, base.labels, "job {i} vs pipeline");
        assert_eq!(concurrent[i].labels, sequential[i].labels, "job {i} concurrency");

        // per-run, per-link counters: byte-for-byte across interleavings
        let (c, s) = (&concurrent[i].report, &sequential[i].report);
        assert_eq!(c.n_codes, s.n_codes, "job {i} codes");
        assert_eq!(c.sigma, s.sigma, "job {i} sigma");
        assert_eq!(c.per_site, s.per_site, "job {i} per-link counters");

        // the run-scoped dialect is exactly 2 frames up (registration +
        // codebook) and 3 down (run open + work order + labels) per site
        for (sid, l) in c.per_site.iter().enumerate() {
            assert_eq!(l.up_frames, 2, "job {i} site {sid} up frames");
            assert_eq!(l.down_frames, 3, "job {i} site {sid} down frames");
        }
        assert_eq!(c.n_codes as usize, base.n_codes, "job {i} codes vs pipeline");
    }
    // two different seeds really are two different clusterings of the
    // same data (guards against comparing a job with itself)
    assert_ne!(concurrent[0].labels, concurrent[1].labels);
}

/// The pipelining acceptance test: with an instrumented slow central for
/// run A (a gate the test holds shut), run B's frames keep being
/// dispatched and B *completes* — labels delivered, `JOBDONE` received —
/// strictly before A's `CentralDone` is processed. Before the worker-pool
/// offload, A's central ran on the reactor thread and B's frames just
/// queued in the mailbox until it finished.
#[test]
fn slow_central_for_one_run_does_not_block_another() {
    let parts = workload();
    let base_a = run_pipeline(&parts, &cfg_with_seed(21)).unwrap();
    let base_b = run_pipeline(&parts, &cfg_with_seed(77)).unwrap();

    let gate = Gate::new();
    let hook = {
        let gate = Arc::clone(&gate);
        Arc::new(move |run: u32| {
            if run == 1 {
                gate.enter_and_wait();
            }
        })
    };
    let cfg = cfg_with_seed(0);
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 2,
            queue_depth: 8,
            allow_label_pull: true,
            central_workers: 2, // A's blocked worker must not starve B
            client_limit: Some(2),
        },
        faults: Vec::new(),
        central_hook: Some(hook),
        hangups: vec![],
    };
    let mut harness = serve_channel(datasets(&parts), &cfg, opts).unwrap();

    let client_a = harness.client();
    let client_b = harness.client();
    let run_a = client_a.submit(&spec_from_config(&cfg_with_seed(21))).unwrap();
    let run_b = client_b.submit(&spec_from_config(&cfg_with_seed(77))).unwrap();
    assert_eq!((run_a, run_b), (1, 2));

    // A's central is in flight and deterministically stuck.
    gate.wait_entered();

    // B runs end to end — sites served, central computed, labels out —
    // while A's central is still blocked: the pipelining proof.
    let report_b = client_b.await_done(run_b).unwrap();
    let labels_b = pull_global(&client_b, run_b, &report_b, &parts);
    assert_eq!(labels_b, base_b.labels);

    // Only now may A finish; its result is unaffected by the stall.
    gate.open();
    let report_a = client_a.await_done(run_a).unwrap();
    let labels_a = pull_global(&client_a, run_a, &report_a, &parts);
    assert_eq!(labels_a, base_a.labels);

    drop(client_a);
    drop(client_b);
    let (stats, _) = harness.join().unwrap();
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.failed, 0);
}

/// A straggler deadline must fire on schedule even while another run's
/// central is in flight: run A blocks in its central, run B's site frames
/// are swallowed by the fault plan, and advancing the virtual clock past
/// `collect_timeout` fails exactly B with the canonical straggler error.
#[test]
fn deadline_fires_during_another_runs_central() {
    let parts = workload();
    let gate = Gate::new();
    let hook = {
        let gate = Arc::clone(&gate);
        Arc::new(move |run: u32| {
            if run == 1 {
                gate.enter_and_wait();
            }
        })
    };
    let mut cfg = cfg_with_seed(0);
    cfg.collect_timeout = Duration::from_secs(5); // virtual seconds
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 2,
            queue_depth: 8,
            allow_label_pull: false,
            central_workers: 2,
            client_limit: Some(2),
        },
        // run 2 never registers: both sites' run-2 frames vanish, while
        // the sites themselves stay healthy (no SiteDown — only the
        // deadline can catch this stall)
        faults: vec![
            Fault::DropRunFrames { site: 0, run: 2 },
            Fault::DropRunFrames { site: 1, run: 2 },
        ],
        central_hook: Some(hook),
        hangups: vec![],
    };
    let mut harness = serve_channel(datasets(&parts), &cfg, opts).unwrap();

    let client_a = harness.client();
    let client_b = harness.client();
    let run_a = client_a.submit(&spec_from_config(&cfg_with_seed(21))).unwrap();
    gate.wait_entered(); // A is mid-central and stuck
    let run_b = client_b.submit(&spec_from_config(&cfg_with_seed(77))).unwrap();
    assert_eq!((run_a, run_b), (1, 2));

    // Advance past B's registration deadline. A has no collect deadline
    // (it is mid-central), so the tick must fail B and only B.
    harness.tick(Duration::from_secs(6));
    let err = client_b.await_done(run_b).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("registration collect failed"), "{msg}");
    assert!(msg.contains("[0, 1]"), "both sites never reported for B: {msg}");

    // A was untouched by the deadline sweep and completes once released.
    gate.open();
    client_a.await_done(run_a).unwrap();

    drop(client_a);
    drop(client_b);
    let (stats, _) = harness.join().unwrap();
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.failed, 1);
}

/// A duplicated run-scoped frame (fault plan, deterministic) fails exactly
/// the run it belongs to — the next job reuses the same sessions and
/// completes with full parity. Also exercises the stale-`CentralDone`
/// path: if the duplicate lands after collection completed, the run dies
/// while its central is in flight and the worker's result is discarded.
#[test]
fn duplicated_codebook_fails_only_its_run() {
    let parts = workload();
    let spec = spec_from_config(&cfg_with_seed(21));
    let base = run_pipeline(&parts, &cfg_with_seed(21)).unwrap();

    let cfg = cfg_with_seed(0);
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 8,
            allow_label_pull: true,
            client_limit: Some(2),
            ..Default::default()
        },
        // site 0's second uplink frame is run 1's codebook: deliver twice
        faults: vec![Fault::DuplicateFrame { site: 0, frame: 2 }],
        ..Default::default()
    };
    let mut harness = serve_channel(datasets(&parts), &cfg, opts).unwrap();

    let client_a = harness.client();
    let run_a = client_a.submit(&spec).unwrap();
    let err = client_a.await_done(run_a).unwrap_err();
    assert!(format!("{err:#}").contains("codebook"), "{err:#}");
    drop(client_a);

    // same sessions, next job: unaffected, full parity
    let client_b = harness.client();
    let run_b = client_b.submit(&spec).unwrap();
    let report_b = client_b.await_done(run_b).unwrap();
    let labels_b = pull_global(&client_b, run_b, &report_b, &parts);
    assert_eq!(labels_b, base.labels);
    drop(client_b);

    let (stats, _) = harness.join().unwrap();
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.failed, 1);
}

/// A severed site link (fault plan) fails the active run; the surviving
/// site's session ends cleanly with the run counted as aborted.
#[test]
fn severed_site_link_fails_the_active_run() {
    let parts = workload();
    let spec = spec_from_config(&cfg_with_seed(21));

    let cfg = cfg_with_seed(0);
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 8,
            allow_label_pull: false,
            client_limit: Some(1),
            ..Default::default()
        },
        // site 1 dies right after delivering run 1's codebook (its 2nd
        // uplink frame) — by then every site has opened the run, so the
        // aborted-run accounting below is order-independent
        faults: vec![Fault::DropSiteAfter { site: 1, frames: 2 }],
        ..Default::default()
    };
    let mut harness = serve_channel(datasets(&parts), &cfg, opts).unwrap();

    let client = harness.client();
    let run = client.submit(&spec).unwrap();
    let err = client.await_done(run).unwrap_err();
    assert!(format!("{err:#}").contains("site 1"), "{err:#}");
    drop(client);

    let (stats, outcomes) = harness.join().unwrap();
    assert_eq!(stats.completed, 0);
    assert_eq!(stats.failed, 1);
    // both sites had the run open (work orders out); it died with the star
    assert_eq!(outcomes[0].runs_served, 0);
    assert_eq!(outcomes[0].aborted_runs, 1);
    assert_eq!(outcomes[1].aborted_runs, 1);
}

/// A hostile or buggy job spec is refused at submit time with a reason —
/// it must never reach the central step, where `k = 0` would panic the
/// reactor and take every client's runs down with it.
#[test]
fn hostile_spec_is_rejected_at_submit() {
    let ds = gmm::paper_mixture_10d(400, 0.1, 51);
    let parts = scenario::split(&ds, Scenario::D3, 1, 51);

    let cfg = cfg_with_seed(51);
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 2,
            allow_label_pull: false,
            client_limit: Some(1),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut harness = serve_channel(datasets(&parts), &cfg, opts).unwrap();

    let client = harness.client();
    let mut bad = spec_from_config(&cfg_with_seed(51));
    bad.k_clusters = 0;
    let err = client.submit(&bad).unwrap_err();
    assert!(format!("{err:#}").contains("bad job spec"), "{err:#}");

    // the connection (and the server) survive the refusal
    let run = client.submit(&spec_from_config(&cfg_with_seed(51))).unwrap();
    client.await_done(run).unwrap();
    drop(client);

    let (stats, outcomes) = harness.join().unwrap();
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.completed, 1);
    assert_eq!(outcomes[0].runs_served, 1);
}

/// `[leader] allow_label_pull` gates the pull plane; an unknown run is
/// refused with a reason; and a run evicted from the site's label cache
/// (`[site] label_cache_runs`, here shrunk to 1) is refused by the site
/// through the leader.
#[test]
fn label_pull_policy_unknown_run_and_eviction() {
    let ds = gmm::paper_mixture_10d(600, 0.1, 33);
    let parts = scenario::split(&ds, Scenario::D3, 1, 33);
    let spec = spec_from_config(&cfg_with_seed(33));

    for allow in [false, true] {
        let mut cfg = cfg_with_seed(33);
        cfg.site.label_cache_runs = 1; // second completed run evicts the first
        let opts = HarnessOpts {
            server: ServerOpts {
                max_jobs: 1,
                queue_depth: 4,
                allow_label_pull: allow,
                client_limit: Some(1),
                ..Default::default()
            },
            ..Default::default()
        };
        let mut harness = serve_channel(datasets(&parts), &cfg, opts).unwrap();

        let client = harness.client();
        let run1 = client.submit(&spec).unwrap();
        let report1 = client.await_done(run1).unwrap();
        if allow {
            let err = client.pull_labels(9999, 1).unwrap_err();
            assert!(format!("{err:#}").contains("not a completed run"), "{err:#}");
            let pulled = client.pull_labels(run1, report1.per_site.len()).unwrap();
            assert_eq!(pulled.len(), 1);
            assert_eq!(pulled[0].1.len(), parts[0].data.len());

            // a second run evicts the first from the 1-deep site cache
            let run2 = client.submit(&spec).unwrap();
            let report2 = client.await_done(run2).unwrap();
            let err = client.pull_labels(run1, report1.per_site.len()).unwrap_err();
            assert!(format!("{err:#}").contains("label cache"), "{err:#}");
            client.pull_labels(run2, report2.per_site.len()).unwrap();
        } else {
            let err = client.pull_labels(run1, report1.per_site.len()).unwrap_err();
            assert!(format!("{err:#}").contains("disabled"), "{err:#}");
        }
        drop(client);
        let (stats, _) = harness.join().unwrap();
        assert_eq!(stats.completed, if allow { 2 } else { 1 });
    }
}

/// The harness refuses to start without a shutdown condition — an
/// unbounded in-process server could never be joined.
#[test]
fn harness_requires_a_client_limit() {
    let ds = gmm::paper_mixture_10d(100, 0.1, 1);
    let parts = scenario::split(&ds, Scenario::D3, 1, 1);
    let opts = HarnessOpts::default(); // client_limit: None
    let err = serve_channel(datasets(&parts), &cfg_with_seed(1), opts).unwrap_err();
    assert!(format!("{err:#}").contains("client_limit"), "{err:#}");
}

/// Token-bucket admission (`[leader] admit_rate` / `admit_burst`) on the
/// virtual clock, no sleeps: a client submitting faster than the rate is
/// refused with `rate limited` exactly when the bucket is empty, a
/// half-second tick refills only half a token (still refused), and the
/// full second's refill admits it again.
#[test]
fn admission_rate_limits_on_the_virtual_clock() {
    let ds = gmm::paper_mixture_10d(400, 0.1, 51);
    let parts = scenario::split(&ds, Scenario::D3, 1, 51);
    let spec = spec_from_config(&cfg_with_seed(51));

    let mut cfg = cfg_with_seed(51);
    cfg.leader.admit_rate = 1.0; // one submit per virtual second…
    cfg.leader.admit_burst = 2; // …above an initial burst of two
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 8,
            allow_label_pull: false,
            client_limit: Some(1),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut harness = serve_channel(datasets(&parts), &cfg, opts).unwrap();

    let client = harness.client();
    // the burst: two tokens, two admits
    let run1 = client.submit(&spec).unwrap();
    let run2 = client.submit(&spec).unwrap();
    // bucket empty: refused, and no run id is burned
    let err = client.submit(&spec).unwrap_err();
    assert!(format!("{err:#}").contains("rate limited"), "{err:#}");

    // half a virtual second is half a token: still refused
    harness.tick(Duration::from_millis(500));
    let err = client.submit(&spec).unwrap_err();
    assert!(format!("{err:#}").contains("rate limited"), "{err:#}");

    // the other half completes the token: admitted again…
    harness.tick(Duration::from_millis(500));
    let run3 = client.submit(&spec).unwrap();
    // …and the very next submit drains it back to empty
    let err = client.submit(&spec).unwrap_err();
    assert!(format!("{err:#}").contains("rate limited"), "{err:#}");

    assert_eq!((run1, run2, run3), (1, 2, 3), "rejects must not consume run ids");
    for run in [run1, run2, run3] {
        client.await_done(run).unwrap();
    }
    drop(client);
    let (stats, _) = harness.join().unwrap();
    assert_eq!(stats.completed, 3);
    assert_eq!(stats.rejected, 3);
}

/// JOBACCEPT2's queue position is the live backlog: it climbs 0,1,2,3 as
/// a burst lands behind a gated central, decreases strictly monotonically
/// for probes submitted as the queue drains, and the ETA is the
/// documented "unknown" sentinel (`ETA_UNKNOWN_NS` = `u64::MAX`) until
/// the leader has a central-duration mean — never a fake `0` that reads
/// as "immediate". Every run's central is individually gated, so each
/// probe lands at an exactly known backlog.
#[test]
fn tracked_accept_position_follows_the_backlog() {
    let ds = gmm::paper_mixture_10d(400, 0.1, 51);
    let parts = scenario::split(&ds, Scenario::D3, 1, 51);
    let spec = spec_from_config(&cfg_with_seed(51));

    let gates: Vec<Arc<Gate>> = (0..8).map(|_| Gate::new()).collect();
    let hook = {
        let gates = gates.clone();
        Arc::new(move |run: u32| gates[(run - 1) as usize].enter_and_wait())
    };
    let cfg = cfg_with_seed(51);
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 8,
            allow_label_pull: false,
            central_workers: 1, // strictly serial centrals
            client_limit: Some(1),
        },
        faults: Vec::new(),
        central_hook: Some(hook),
        hangups: vec![],
    };
    let mut harness = serve_channel(datasets(&parts), &cfg, opts).unwrap();
    let client = harness.client();

    // fill: positions climb with the backlog; no central has completed,
    // so every ETA is the unknown sentinel, not a bogus "0 ns from now"
    let a1 = client.submit_tracked(&spec).unwrap();
    assert_eq!((a1.run, a1.position, a1.eta_ns), (1, 0, ETA_UNKNOWN_NS));
    gates[0].wait_entered(); // run 1 is mid-central and held
    let accepts: Vec<_> =
        (0..3).map(|_| client.submit_tracked(&spec).unwrap()).collect();
    for (i, a) in accepts.iter().enumerate() {
        assert_eq!(a.position as usize, i + 1, "fill position of run {}", a.run);
        assert_eq!(
            a.eta_ns, ETA_UNKNOWN_NS,
            "no central mean yet for run {} — the ETA must say so, not claim 0",
            a.run
        );
    }

    // drain, probing between completions: each probe sees a strictly
    // smaller backlog than the one before
    let mut drained = 0u32;
    let mut probes = Vec::new();
    for k in 0..3 {
        // complete (k+1) runs, leaving the next one held mid-central
        for _ in 0..=k.min(1) {
            gates[drained as usize].open();
            client.await_done(drained + 1).unwrap();
            drained += 1;
            gates[drained as usize].wait_entered();
        }
        probes.push(client.submit_tracked(&spec).unwrap());
    }
    assert_eq!(
        probes.iter().map(|a| a.position).collect::<Vec<_>>(),
        vec![3, 2, 1],
        "probe positions must decrease as the queue drains"
    );
    for a in &probes {
        assert!(
            a.eta_ns > 0 && a.eta_ns != ETA_UNKNOWN_NS,
            "run {}: mean central is known, ETA must be a real estimate",
            a.run
        );
    }

    // release everything still held (runs 6 and 7 are mid-central or
    // queued behind it) and drain the tail
    for run in drained + 1..=7 {
        gates[(run - 1) as usize].wait_entered();
        gates[(run - 1) as usize].open();
        client.await_done(run).unwrap();
    }

    // idle server: position resets to 0 (nothing is ahead, so the ETA is
    // 0 again by `eta ≈ position × mean`)
    let idle = client.submit_tracked(&spec).unwrap();
    assert_eq!((idle.run, idle.position, idle.eta_ns), (8, 0, 0));
    gates[7].wait_entered();
    gates[7].open();
    client.await_done(8).unwrap();

    drop(client);
    let (stats, _) = harness.join().unwrap();
    assert_eq!(stats.completed, 8);
    assert_eq!(stats.rejected, 0);
}

/// Under `[leader] fair_queue`, JOBACCEPT2's position is the client's
/// place in the *DRR lane schedule*, not the raw backlog count: a fresh
/// tenant submitting behind another tenant's pile is served at the next
/// round-robin visit, and the accept frame must say so. Here tenant A
/// queues three jobs behind its own gated run; tenant B's first submit
/// then lands at position 2 (one active + one A job ahead), where the
/// backlog-blind count would claim position 4.
#[test]
fn fair_queue_accept_position_follows_the_drr_schedule() {
    let ds = gmm::paper_mixture_10d(400, 0.1, 51);
    let parts = scenario::split(&ds, Scenario::D3, 1, 51);
    let spec = spec_from_config(&cfg_with_seed(51));

    let gates: Vec<Arc<Gate>> = (0..5).map(|_| Gate::new()).collect();
    let hook = {
        let gates = gates.clone();
        Arc::new(move |run: u32| gates[(run - 1) as usize].enter_and_wait())
    };
    let mut cfg = cfg_with_seed(51);
    cfg.leader.fair_queue = true;
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 8,
            allow_label_pull: false,
            central_workers: 1,
            client_limit: Some(2),
        },
        faults: Vec::new(),
        central_hook: Some(hook),
        hangups: vec![],
    };
    let mut harness = serve_channel(datasets(&parts), &cfg, opts).unwrap();
    let client_a = harness.client();
    let client_b = harness.client();

    // tenant A: run 1 starts and is held mid-central; runs 2..4 queue up
    // in A's lane — a single lane is FIFO, so positions climb 1,2,3
    let a1 = client_a.submit_tracked(&spec).unwrap();
    assert_eq!((a1.run, a1.position), (1, 0));
    gates[0].wait_entered();
    for expect in 1..=3u32 {
        let a = client_a.submit_tracked(&spec).unwrap();
        assert_eq!(a.position, expect, "fill position of run {}", a.run);
    }

    // tenant B's first job: the backlog holds 3 A jobs, but DRR serves B
    // at the very next lane visit — one active run + one A job ahead
    let b = client_b.submit_tracked(&spec).unwrap();
    assert_eq!(
        b.position, 2,
        "run {}: DRR schedule puts a fresh tenant at the next visit, \
         not behind the whole backlog",
        b.run
    );
    assert_eq!(b.eta_ns, ETA_UNKNOWN_NS, "no central mean yet");

    // let everything finish (pop order is DRR: 1, 2, 5, 3, 4 — the gates
    // are per-run, so opening them all up front is order-independent)
    for g in &gates {
        g.open();
    }
    for run in [1, 2, 3, 4] {
        client_a.await_done(run).unwrap();
    }
    client_b.await_done(b.run).unwrap();
    drop(client_a);
    drop(client_b);
    let (stats, _) = harness.join().unwrap();
    assert_eq!(stats.completed, 5);
}

/// A site link that dies on an otherwise idle server is re-dialed on the
/// backoff schedule, not at the next submit: `site_down` arms the retry
/// deadline, `next_deadline` turns it into a wakeup, and `try_start_jobs`
/// fires the re-dial even with an empty queue. Channel links can never
/// actually be revived, so the observable is the harness's attempt
/// counter — pre-fix it stays at zero forever because nothing ever wakes
/// the star back up.
#[test]
fn severed_site_is_redialed_on_schedule_while_idle() {
    let parts = workload();
    let spec = spec_from_config(&cfg_with_seed(21));

    let cfg = cfg_with_seed(0);
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 8,
            allow_label_pull: false,
            client_limit: Some(1),
            ..Default::default()
        },
        // site 1 dies right after delivering run 1's codebook
        faults: vec![Fault::DropSiteAfter { site: 1, frames: 2 }],
        ..Default::default()
    };
    let mut harness = serve_channel(datasets(&parts), &cfg, opts).unwrap();

    let client = harness.client();
    let run = client.submit(&spec).unwrap();
    let err = client.await_done(run).unwrap_err();
    assert!(format!("{err:#}").contains("site 1"), "{err:#}");

    // the server is now idle (nothing queued, nothing active) with a dead
    // link; every tick past the armed deadline must attempt a re-dial.
    // 20 virtual seconds clears the backoff cap (10s) each time.
    for _ in 0..5 {
        harness.tick(Duration::from_secs(20));
    }
    // ticks are asynchronous: wait (in real time) for the reactor to have
    // drained them rather than racing it
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while harness.redial_attempts() < 5 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(
        harness.redial_attempts() >= 5,
        "idle server re-dialed only {} time(s) across 5 expired backoff windows",
        harness.redial_attempts()
    );

    drop(client);
    let (stats, outcomes) = harness.join().unwrap();
    assert_eq!(stats.completed, 0);
    assert_eq!(stats.failed, 1);
    assert_eq!(outcomes[1].aborted_runs, 1);
}

/// Reuse-of-harness sanity: the typed client API is the same one `dsc
/// submit` uses over TCP, so one client can carry several jobs with
/// interleaved completions buffered correctly.
#[test]
fn one_client_carries_two_interleaved_jobs() {
    let parts = workload();
    let cfg = cfg_with_seed(0);
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 2,
            queue_depth: 8,
            allow_label_pull: false,
            client_limit: Some(1),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut harness = serve_channel(datasets(&parts), &cfg, opts).unwrap();

    let client = harness.client();
    let run_a = client.submit(&spec_from_config(&cfg_with_seed(21))).unwrap();
    let run_b = client.submit(&spec_from_config(&cfg_with_seed(77))).unwrap();
    // await in reverse submission order: the earlier JOBDONE (whichever
    // finishes first) is buffered, not lost
    let report_b = client.await_done(run_b).unwrap();
    let report_a = client.await_done(run_a).unwrap();
    assert!(report_a.n_codes > 0 && report_b.n_codes > 0);
    drop(client);

    let (stats, _) = harness.join().unwrap();
    assert_eq!(stats.completed, 2);
}
