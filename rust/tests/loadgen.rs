//! The deterministic load generator, end to end: same mix ⇒ same report
//! (bit for bit), and DRR weighted fair queueing beats global FIFO on the
//! canonical skewed 3-tenant mix. These are the guarantees the recorded
//! BENCH trajectory (`benches/jobserver_load.rs` →
//! `bench_out/BENCH_jobserver.json`) is built on; `docs/TESTING.md`
//! explains how to read the numbers.

use dsc::coordinator::loadgen::{run_channel_load, run_channel_load_journaled, LoadMix};

/// Determinism is the load generator's whole contract: virtual time,
/// sequenced centrals and up-front submission make the report a pure
/// function of the mix — including the f64s, so `PartialEq` is exact.
#[test]
fn same_mix_produces_the_same_report_bit_for_bit() {
    let a = run_channel_load(&LoadMix::skewed_three(true)).unwrap();
    let b = run_channel_load(&LoadMix::skewed_three(true)).unwrap();
    assert_eq!(a, b);
    assert_eq!(a.to_json(), b.to_json());

    assert_eq!(a.jobs, 21);
    assert_eq!(a.completed, 21);
    assert_eq!(a.rejected, 0);
    assert_eq!(a.per_client.len(), 3);
    // every tenant's full budget was served
    assert_eq!(a.per_client[0].jobs, 12);
    assert_eq!(a.per_client[1].jobs, 6);
    assert_eq!(a.per_client[2].jobs, 3);
}

/// The FIFO-vs-DRR comparison the bench records: under the skewed mix,
/// DRR's weight-normalized service is near-uniform (Jain ≈ 1) while FIFO
/// — which ignores priorities — scores visibly lower, and the
/// high-weight light tenant really does see lower sojourns while the
/// heavy low-weight tenant pays for them.
#[test]
fn drr_beats_fifo_on_the_skewed_mix() {
    let fifo = run_channel_load(&LoadMix::skewed_three(false)).unwrap();
    let drr = run_channel_load(&LoadMix::skewed_three(true)).unwrap();
    assert_eq!(fifo.completed, 21);
    assert_eq!(drr.completed, 21);

    assert!(drr.fairness > 0.95, "drr fairness {}", drr.fairness);
    assert!(fifo.fairness < 0.85, "fifo fairness {}", fifo.fairness);
    assert!(
        drr.fairness > fifo.fairness + 0.1,
        "fairness gap collapsed: drr {} vs fifo {}",
        drr.fairness,
        fifo.fairness
    );

    // weight 4, 3 jobs: served earlier under DRR than under FIFO
    assert!(
        drr.per_client[2].mean_ns < fifo.per_client[2].mean_ns,
        "w4 tenant: drr {} vs fifo {}",
        drr.per_client[2].mean_ns,
        fifo.per_client[2].mean_ns
    );
    // weight 1, 12 jobs: the tenant that pays under fair queueing
    assert!(drr.per_client[0].mean_ns >= fifo.per_client[0].mean_ns);

    // one job per virtual step either way: the service slot never idles
    assert!(fifo.utilization > 0.999 && drr.utilization > 0.999);
    assert!(fifo.throughput_jobs_per_sec > 0.0);
    assert_eq!(fifo.makespan_ns, drr.makespan_ns);
}

/// Journaling spends wall time only — the virtual-time report must not
/// move by a single bit when the reactor event-sources the run, and the
/// journal it leaves behind must recover cleanly with every run's full
/// admit→start→complete life cycle on record.
#[test]
fn journaling_does_not_move_the_report() {
    let path = std::env::temp_dir()
        .join(format!("dsc-loadgen-journal-{}.journal", std::process::id()));
    let _ = std::fs::remove_file(&path);

    let plain = run_channel_load(&LoadMix::skewed_three(true)).unwrap();
    let journaled =
        run_channel_load_journaled(&LoadMix::skewed_three(true), &path, false).unwrap();
    assert_eq!(journaled, plain, "journaling moved the deterministic report");

    let recovered = dsc::coordinator::journal::recover(&path).unwrap();
    assert!(!recovered.torn);
    let count = |f: fn(&dsc::coordinator::journal::JournalEvent) -> bool| {
        recovered.records.iter().filter(|r| f(&r.event)).count()
    };
    use dsc::coordinator::journal::JournalEvent as E;
    assert_eq!(count(|e| matches!(e, E::Admitted { .. })), 21);
    assert_eq!(count(|e| matches!(e, E::Started { .. })), 21);
    assert_eq!(count(|e| matches!(e, E::Completed { .. })), 21);
    assert_eq!(count(|e| matches!(e, E::Failed { .. })), 0);
    let _ = std::fs::remove_file(&path);
}
