//! The chaos load mix (`coordinator::loadgen::run_chaos_mix`): one
//! six-job, three-tenant DRR plan run under fire — both sites silently
//! stall run 1 (the straggler deadline must catch it), the journaling
//! leader is crashed and recovered the moment all six admissions are on
//! record, and site 1's uplink is severed at the last pop of the
//! recovered backlog. The contract: only the two faulted runs fail, and
//! every survivor matches the fault-free twin bit for bit — including
//! per-site link-byte counters and the DML result cache behaviour for
//! the repeated seed-55 spec. `docs/TESTING.md` has the reading guide.

use dsc::coordinator::loadgen::{run_chaos_mix, run_chaos_twin, ChaosRun};

#[test]
fn chaos_mix_fails_only_the_faulted_runs_and_survivors_match_the_twin() {
    // ── the fault-free twin: the reference, and proof the plan is clean ──
    let twin = run_chaos_twin().unwrap();
    assert_eq!(twin.runs, vec![1, 2, 3, 4, 5, 6]);
    assert_eq!((twin.completed, twin.failed, twin.rejected), (6, 0, 0));
    assert_eq!(twin.pop_order.len(), 6);
    assert_eq!(twin.pop_order[0], 1, "the first submit starts before any backlog forms");
    assert_eq!(twin.journal_records, 0, "the twin does not journal");
    for (site, s) in twin.sessions.iter().enumerate() {
        assert_eq!(
            *s,
            (6, 0, 5, 1),
            "site {site}: six served, five DML passes, one cache hit for the repeated spec"
        );
    }
    // submissions 3 and 5 carry the same spec (seed 55): one computed,
    // one replayed from the sites' DML cache — indistinguishable results
    assert!(matches!(twin.results[2], ChaosRun::Done { .. }));
    assert_eq!(twin.results[4], twin.results[2], "cache replay diverged from the compute");

    // ── the same plan under the fault plan + crash ───────────────────────
    let path = std::env::temp_dir()
        .join(format!("dsc-chaos-mix-{}.journal", std::process::id()));
    let chaos = run_chaos_mix(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    assert_eq!(chaos.runs, twin.runs);
    assert_eq!((chaos.completed, chaos.failed, chaos.rejected), (4, 2, 0));

    // exactly two casualties, each with its own failure mode on record
    let failed: Vec<usize> = chaos
        .results
        .iter()
        .enumerate()
        .filter_map(|(i, r)| matches!(r, ChaosRun::Failed { .. }).then_some(i))
        .collect();
    assert_eq!(failed.len(), 2, "exactly the faulted runs may fail: {:?}", chaos.results);
    assert_eq!(failed[0], 0, "run 1 is the stalled straggler");
    match &chaos.results[0] {
        ChaosRun::Failed { err } => {
            assert!(err.contains("never reported"), "straggler error: {err}")
        }
        other => panic!("run 1 should fail on the collect deadline, got {other:?}"),
    }
    match &chaos.results[failed[1]] {
        ChaosRun::Failed { err } => {
            assert!(err.contains("site 1 link failed"), "outage error: {err}")
        }
        other => panic!("the severed run should fail on the site outage, got {other:?}"),
    }

    // every survivor matches its fault-free twin bit for bit — n_codes,
    // sigma, and the per-site link-byte counters
    for (i, r) in chaos.results.iter().enumerate() {
        if matches!(r, ChaosRun::Done { .. }) {
            assert_eq!(r, &twin.results[i], "survivor run {} diverged from its twin", i + 1);
        }
    }

    // four pops reached their central, all from the recovered backlog —
    // never the stalled run 1
    assert_eq!(chaos.pop_order.len(), 4);
    assert!(!chaos.pop_order.contains(&1));
    assert!(chaos.pop_order.iter().all(|r| (2..=6).contains(r)));

    // both sites fully served every survivor (labels delivered before the
    // severance, which strikes the final pop's registration)
    for (site, s) in chaos.sessions.iter().enumerate() {
        assert_eq!(s.0, 4, "site {site} must fully serve all four survivors");
    }

    // the journal kept recording past the replayed 13-record prefix:
    // recovery resumed event-sourcing, it did not fork a fresh log
    assert!(chaos.journal_records > 13, "journal held {} records", chaos.journal_records);

    // The DML cache under fire: when both seed-55 runs survive, the
    // replay equals the compute and each site logged a hit. (Whether the
    // severed fifth pop's work order reached the sites before the
    // site-down is a real-time race, so per-site pass/hit totals are
    // pinned only in the twin.)
    if matches!(
        (&chaos.results[2], &chaos.results[4]),
        (ChaosRun::Done { .. }, ChaosRun::Done { .. })
    ) {
        assert_eq!(chaos.results[4], chaos.results[2]);
        for (site, s) in chaos.sessions.iter().enumerate() {
            assert!(s.3 >= 1, "site {site} should hit the cache for the repeated spec");
        }
    }
}
