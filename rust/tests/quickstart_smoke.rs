//! Smoke test for the README / `examples/quickstart.rs` path: synthesize
//! the paper's 4-component Gaussian mixture, split it across sites, run the
//! full distributed pipeline with the default (non-XLA) eigensolver, and
//! check the accuracy report — the zero-to-working journey a new user
//! takes, pinned as a test so it can never silently rot.

use dsc::config::{Backend, PipelineConfig};
use dsc::coordinator::run_pipeline;
use dsc::data::gmm;
use dsc::data::scenario::{self, Scenario};
use dsc::spectral::Bandwidth;

/// GMM → split → pipeline → accuracy report, with the paper's 10-D
/// 4-component mixture at its easiest covariance setting (ρ = 0.1, Fig. 6's
/// leftmost column, where the paper reports ≈ 0.93) and the 40:1 codeword
/// compression. The default backend must be the pure-Rust eigensolver, and
/// the report must come back complete and ≥ 0.9 accurate.
#[test]
fn quickstart_path_reports_high_accuracy() {
    let ds = gmm::paper_mixture_10d(12_000, 0.1, 7);
    let parts = scenario::split(&ds, Scenario::D3, 2, 7);
    let cfg = PipelineConfig {
        total_codes: 300, // 40:1, the paper's ratio
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed: 7,
        ..Default::default()
    };
    assert_eq!(cfg.backend, Backend::Native, "default backend must not need XLA");

    let report = run_pipeline(&parts, &cfg).expect("quickstart pipeline must complete");

    // the report is complete and self-consistent
    assert_eq!(report.labels.len(), ds.len());
    assert!(report.labels.iter().all(|&l| (l as usize) < 4));
    assert!(report.n_codes >= 290 && report.n_codes <= 310, "{}", report.n_codes);
    assert!(report.sigma > 0.0);
    assert_eq!(report.site_dml.len(), 2);
    assert!(report.net.total_bytes() > 0);
    assert!(report.net.total_bytes() < report.full_data_bytes / 10);

    // the paper's accuracy regime for ρ = 0.1
    assert!(
        report.accuracy >= 0.9,
        "quickstart accuracy {:.4} (ARI {:.4}, NMI {:.4}) below the 0.9 floor",
        report.accuracy,
        report.ari,
        report.nmi
    );
}

/// The same path must also hold on the size-skewed D4 split (one big site,
/// one small), since the proportional codeword budget is what keeps the
/// small site from being over-compressed.
#[test]
fn quickstart_path_survives_skewed_sites() {
    let ds = gmm::paper_mixture_10d(8_000, 0.1, 9);
    let parts = scenario::split(&ds, Scenario::D4, 2, 9);
    assert!(parts[0].data.len() > parts[1].data.len());
    let cfg = PipelineConfig {
        total_codes: 200,
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed: 9,
        ..Default::default()
    };
    let report = run_pipeline(&parts, &cfg).expect("D4 pipeline must complete");
    assert_eq!(report.labels.len(), ds.len());
    assert!(report.accuracy >= 0.85, "D4 accuracy {:.4}", report.accuracy);
}
