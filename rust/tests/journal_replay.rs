//! Crash-safe leader: the journal + replay proof.
//!
//! The headline is a **crash-point sweep**: a canonical three-tenant DRR
//! mix (one run stalled by a site fault until the straggler deadline, one
//! run's central deterministically slow behind a gate) is journaled once
//! uninterrupted, and then re-run once per journal record index K,
//! crashing the reactor the moment the log holds K records and recovering
//! it with [`ChannelHarness::crash_and_restart`]. Every client-visible
//! outcome — accepted run ids, queue positions and ETAs, failure texts,
//! reports with per-link byte counters, pulled labels — plus the journal's
//! own durable pop order must equal the uninterrupted twin's, bit for bit.
//! CI runs this file under `DSC_THREADS=1` and `=4` (docs/TESTING.md).
//!
//! The corruption suite mirrors `properties.rs`'s truncation-rejection
//! sweeps at the journal layer: a file cut at *every* byte offset recovers
//! cleanly to the longest whole-record prefix (a torn tail is what a crash
//! legitimately leaves behind), while a flipped byte or bad magic anywhere
//! before the tail fails loudly naming the record and byte offset.

mod common;

use std::fs;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use common::pull_global;
use dsc::config::PipelineConfig;
use dsc::coordinator::harness::{
    serve_channel_journaled, ChannelLink, HarnessOpts, HarnessTicker,
};
use dsc::coordinator::journal::{recover, JournalEvent};
use dsc::coordinator::server::{JobClient, ServerOpts};
use dsc::coordinator::{run_pipeline, spec_from_config};
use dsc::data::gmm;
use dsc::data::scenario::{self, Scenario, SitePart};
use dsc::data::Dataset;
use dsc::net::channel::Fault;
use dsc::net::{JobSpec, LinkReport};
use dsc::spectral::Bandwidth;

fn workload() -> Vec<SitePart> {
    // Small on purpose: the sweep replays the whole mix once per record.
    let ds = gmm::paper_mixture_10d(600, 0.1, 21);
    scenario::split(&ds, Scenario::D3, 2, 21)
}

fn datasets(parts: &[SitePart]) -> Vec<Dataset> {
    parts.iter().map(|p| p.data.clone()).collect()
}

fn cfg_with_seed(seed: u64) -> PipelineConfig {
    PipelineConfig {
        total_codes: 32,
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed,
        ..Default::default()
    }
}

fn spec(seed: u64, priority: u32) -> JobSpec {
    let mut spec = spec_from_config(&cfg_with_seed(seed));
    spec.priority = priority;
    spec
}

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dsc-jr-{}-{tag}.journal", std::process::id()))
}

/// Two-phase central gate (same shape as `channel_harness.rs`): the worker
/// announces it entered run 2's central, then blocks until the script
/// opens it.
struct Gate {
    entered: Mutex<bool>,
    entered_cv: Condvar,
    open: Mutex<bool>,
    open_cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate {
            entered: Mutex::new(false),
            entered_cv: Condvar::new(),
            open: Mutex::new(false),
            open_cv: Condvar::new(),
        })
    }

    fn enter_and_wait(&self) {
        *self.entered.lock().unwrap() = true;
        self.entered_cv.notify_all();
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.open_cv.wait(open).unwrap();
        }
    }

    fn wait_entered(&self) {
        let mut entered = self.entered.lock().unwrap();
        while !*entered {
            entered = self.entered_cv.wait(entered).unwrap();
        }
    }

    fn open(&self) {
        *self.open.lock().unwrap() = true;
        self.open_cv.notify_all();
    }
}

/// Everything a client of the canonical mix can observe, in one
/// `PartialEq` bundle. `central_ns` is deliberately absent: it is real
/// compute wall time (the one nondeterministic field a report carries);
/// everything else — including the virtual `wall_ns` and the modeled
/// per-link counters — must reproduce exactly.
#[derive(Debug, PartialEq)]
struct Outcome {
    run1: u32,
    err1: String,
    /// `(run, position, eta_ns)` of the four tracked accepts, send order.
    tracked: Vec<(u32, u32, u64)>,
    run6: u32,
    /// `(run, n_codes, sigma, wall_ns, per_site)` per completed run.
    reports: Vec<(u32, u32, f64, u64, Vec<LinkReport>)>,
    /// `(run, global labels)` per completed run.
    labels: Vec<(u32, Vec<u16>)>,
}

/// The canonical three-tenant mix, driven through three already-minted
/// clients. Tenant A speaks the legacy dialect at priority 1 and its first
/// run stalls (both sites' run-1 frames are swallowed — only the straggler
/// deadline catches it); tenants B and C speak the modern dialect at DRR
/// weights 2 and 4; run 2's central blocks on `gate` until the script has
/// proven it stuck. Every client action is sequential, so the reactor's
/// event order — and with it the journal — is a pure function of this
/// script.
fn drive_script(
    clients: Vec<JobClient<ChannelLink>>,
    ticker: HarnessTicker,
    gate: Arc<Gate>,
    parts: Arc<Vec<SitePart>>,
) -> Outcome {
    let mut clients = clients.into_iter();
    let (a, b, c) = (
        clients.next().unwrap(),
        clients.next().unwrap(),
        clients.next().unwrap(),
    );
    let run1 = a.submit(&spec(21, JobSpec::DEFAULT_PRIORITY)).unwrap();
    let b1 = b.submit_tracked(&spec(33, 2)).unwrap();
    let c1 = c.submit_tracked(&spec(55, 4)).unwrap();
    let b2 = b.submit_tracked(&spec(34, 2)).unwrap();
    let c2 = c.submit_tracked(&spec(56, 4)).unwrap();
    let run6 = a.submit(&spec(22, JobSpec::DEFAULT_PRIORITY)).unwrap();

    // Past run 1's collect deadline: it fails, freeing the single job slot
    // for the DRR backlog built up above.
    ticker.tick(Duration::from_secs(6));
    let err1 = format!("{:#}", a.await_done(run1).unwrap_err());

    // Run 2's central really blocked once, then history may flow.
    gate.wait_entered();
    gate.open();

    let mut reports = Vec::new();
    let mut labels = Vec::new();
    for (client, run) in
        [(&b, b1.run), (&c, c1.run), (&b, b2.run), (&c, c2.run), (&a, run6)]
    {
        let report = client.await_done(run).unwrap();
        labels.push((run, pull_global(client, run, &report, &parts)));
        reports.push((run, report.n_codes, report.sigma, report.wall_ns, report.per_site));
    }
    drop((a, b, c)); // all three tenants gone: the server may shut down
    Outcome {
        run1,
        err1,
        tracked: vec![
            (b1.run, b1.position, b1.eta_ns),
            (c1.run, c1.position, c1.eta_ns),
            (b2.run, b2.position, b2.eta_ns),
            (c2.run, c2.position, c2.eta_ns),
        ],
        run6,
        reports,
        labels,
    }
}

fn mix_cfg() -> PipelineConfig {
    let mut cfg = cfg_with_seed(0);
    cfg.collect_timeout = Duration::from_secs(5); // virtual seconds
    cfg.leader.fair_queue = true;
    cfg
}

fn mix_opts(gate: &Arc<Gate>) -> HarnessOpts {
    let hook = {
        let gate = Arc::clone(gate);
        Arc::new(move |run: u32| {
            if run == 2 {
                gate.enter_and_wait();
            }
        })
    };
    HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 8,
            allow_label_pull: true,
            central_workers: 1,
            client_limit: Some(3),
        },
        faults: vec![
            Fault::DropRunFrames { site: 0, run: 1 },
            Fault::DropRunFrames { site: 1, run: 1 },
        ],
        central_hook: Some(hook),
        hangups: vec![],
    }
}

/// What one full execution of the mix left behind, journal included.
struct Executed {
    outcome: Outcome,
    stats: (u64, u64, u64),
    sessions: Vec<(usize, usize)>,
    /// Queue pop order, from the durable `Started` annotations.
    started: Vec<u32>,
    /// `Admitted` run order and `Failed`/`Completed` orders.
    admitted: Vec<u32>,
    finished: Vec<(u32, bool)>,
    records: u64,
}

/// Run the mix once against `journal_path`, crashing after `crash_after`
/// records (and recovering) when given.
fn execute(parts: &Arc<Vec<SitePart>>, journal_path: &PathBuf, crash_after: Option<u64>) -> Executed {
    let _ = fs::remove_file(journal_path);
    let gate = Gate::new();
    let mut harness = serve_channel_journaled(
        datasets(parts),
        &mix_cfg(),
        mix_opts(&gate),
        journal_path,
        crash_after,
    )
    .unwrap();
    let clients = vec![harness.client(), harness.client(), harness.client()];
    let ticker = harness.ticker();
    let script = {
        let parts = Arc::clone(parts);
        let gate = Arc::clone(&gate);
        std::thread::spawn(move || drive_script(clients, ticker, gate, parts))
    };
    if crash_after.is_some() {
        // Blocks until the reactor hits its crash point mid-script, then
        // replays the journal and resumes against the surviving world.
        harness.crash_and_restart().unwrap();
    }
    let outcome = script.join().expect("script thread panicked");
    let (stats, outcomes) = harness.join().unwrap();

    let recovered = recover(journal_path).unwrap();
    assert!(!recovered.torn, "a synced journal must not have a torn tail");
    let mut started = Vec::new();
    let mut admitted = Vec::new();
    let mut finished = Vec::new();
    for rec in &recovered.records {
        match rec.event {
            JournalEvent::Started { run } => started.push(run),
            JournalEvent::Admitted { run, .. } => admitted.push(run),
            JournalEvent::Completed { run } => finished.push((run, true)),
            JournalEvent::Failed { run } => finished.push((run, false)),
            _ => {}
        }
    }
    Executed {
        outcome,
        stats: (stats.completed, stats.failed, stats.rejected),
        sessions: outcomes.iter().map(|o| (o.runs_served, o.aborted_runs)).collect(),
        started,
        admitted,
        finished,
        records: recovered.records.len() as u64,
    }
}

/// The headline: for every journal record index K of the canonical mix,
/// crash-after-K + replay equals the uninterrupted execution — labels,
/// per-link byte counters, queue pop order, and every client-visible
/// reply, bit for bit.
#[test]
fn crash_point_sweep_replays_bit_identically() {
    let parts = Arc::new(workload());
    let path = temp_path("sweep");

    let reference = execute(&parts, &path, None);
    // Anchor the reference against the in-process pipeline: journaling on
    // is not allowed to change what a job computes.
    let base = run_pipeline(&parts, &cfg_with_seed(33)).unwrap();
    let run2_labels =
        &reference.outcome.labels.iter().find(|(run, _)| *run == 2).unwrap().1;
    assert_eq!(run2_labels, &base.labels, "journaled run 2 vs pipeline");
    assert_eq!(reference.stats, (5, 1, 0));
    assert_eq!(reference.admitted, vec![1, 2, 3, 4, 5, 6]);
    assert!(reference.records > 0);

    for k in 1..=reference.records {
        let replayed = execute(&parts, &path, Some(k));
        assert_eq!(replayed.outcome, reference.outcome, "crash at record {k}");
        assert_eq!(replayed.stats, reference.stats, "crash at record {k}: stats");
        assert_eq!(
            replayed.sessions, reference.sessions,
            "crash at record {k}: site sessions"
        );
        assert_eq!(
            replayed.started, reference.started,
            "crash at record {k}: queue pop order"
        );
        assert_eq!(replayed.admitted, reference.admitted, "crash at record {k}");
        assert_eq!(replayed.finished, reference.finished, "crash at record {k}");
        assert_eq!(
            replayed.records, reference.records,
            "crash at record {k}: journal length"
        );
    }
    let _ = fs::remove_file(&path);
}

// ─── send-failure sweep ────────────────────────────────────────────────────

/// One site holding the whole dataset: the hangup lever needs a mix whose
/// journal record count cannot depend on cross-site arrival races.
fn severed_workload() -> Vec<SitePart> {
    let ds = gmm::paper_mixture_10d(400, 0.1, 11);
    let frac = vec![vec![1.0; ds.n_classes]];
    scenario::split_by_fractions(&ds, &frac, 11)
}

fn severed_opts() -> HarnessOpts {
    HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 4,
            allow_label_pull: false,
            central_workers: 1,
            client_limit: Some(1),
        },
        faults: vec![],
        central_hook: None,
        // The site's third uplink frame is its RUNSITEINFO for run 2: it
        // hangs up just before sending it, so the leader's RUNDMLREQUEST
        // reply is the first send that fails — mid-step, after the
        // triggering SITEFRAME record is already journaled.
        hangups: vec![(0, 3)],
    }
}

/// Everything the severed mix's one client observes, plus the stats.
#[derive(Debug, PartialEq)]
struct SeveredRun {
    run1: u32,
    /// `(n_codes, sigma, wall_ns, per_site)` of the completed run.
    report1: (u32, f64, u64, Vec<LinkReport>),
    run2: u32,
    err2: String,
    stats: (u64, u64, u64),
    sessions: Vec<(usize, usize)>,
    records: u64,
    /// `SendFail` records in the recovered journal.
    send_fails: u64,
}

fn execute_severed(
    parts: &Arc<Vec<SitePart>>,
    journal_path: &PathBuf,
    crash_after: Option<u64>,
) -> SeveredRun {
    let _ = fs::remove_file(journal_path);
    let mut harness = serve_channel_journaled(
        datasets(parts),
        &cfg_with_seed(11),
        severed_opts(),
        journal_path,
        crash_after,
    )
    .unwrap();
    let client = harness.client();
    let script = std::thread::spawn(move || {
        let run1 = client.submit(&spec(11, JobSpec::DEFAULT_PRIORITY)).unwrap();
        let report = client.await_done(run1).unwrap();
        let run2 = client.submit(&spec(12, JobSpec::DEFAULT_PRIORITY)).unwrap();
        let err2 = format!("{:#}", client.await_done(run2).unwrap_err());
        drop(client);
        (run1, (report.n_codes, report.sigma, report.wall_ns, report.per_site), run2, err2)
    });
    if crash_after.is_some() {
        harness.crash_and_restart().unwrap();
    }
    let (run1, report1, run2, err2) = script.join().expect("script thread panicked");
    let (stats, outcomes) = harness.join().unwrap();

    let recovered = recover(journal_path).unwrap();
    assert!(!recovered.torn, "a synced journal must not have a torn tail");
    let send_fails = recovered
        .records
        .iter()
        .filter(|r| matches!(r.event, JournalEvent::SendFail { .. }))
        .count() as u64;
    SeveredRun {
        run1,
        report1,
        run2,
        err2,
        stats: (stats.completed, stats.failed, stats.rejected),
        sessions: outcomes.iter().map(|o| (o.runs_served, o.aborted_runs)).collect(),
        records: recovered.records.len() as u64,
        send_fails,
    }
}

/// The send-failure twin of the headline sweep. A live send failure takes
/// state down *mid-step* — something no journaled mailbox event can
/// re-enact on its own, since the replay driver's sends succeed while a
/// link is up. The journaled `SendFail` record (re-failed by send ordinal
/// during replay) must make every crash point recover to the
/// uninterrupted execution exactly: same failure text on the client, same
/// link generations (checked inside `crash_and_restart`), same journal.
#[test]
fn severed_link_crash_sweep_replays_bit_identically() {
    let parts = Arc::new(severed_workload());
    let path = temp_path("severed");

    let reference = execute_severed(&parts, &path, None);
    assert_eq!(reference.stats, (1, 1, 0), "one completed, one failed by the hangup");
    assert_eq!(reference.send_fails, 1, "the failed RUNDMLREQUEST send is journaled");
    assert!(
        reference.err2.contains("site 0 link failed"),
        "run 2 fails on the severed link: {}",
        reference.err2
    );
    assert!(reference.records > 0);

    for k in 1..=reference.records {
        let replayed = execute_severed(&parts, &path, Some(k));
        assert_eq!(replayed, reference, "crash at record {k}");
    }
    let _ = fs::remove_file(&path);
}

// ─── journal corruption ────────────────────────────────────────────────────

/// A single completed run's journal, for byte-level abuse.
fn small_journal(path: &PathBuf) -> Vec<u8> {
    let _ = fs::remove_file(path);
    let ds = gmm::paper_mixture_10d(300, 0.1, 7);
    let parts = scenario::split(&ds, Scenario::D3, 2, 7);
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 4,
            allow_label_pull: false,
            client_limit: Some(1),
            ..Default::default()
        },
        ..Default::default()
    };
    let mut harness =
        serve_channel_journaled(datasets(&parts), &cfg_with_seed(7), opts, path, None).unwrap();
    let client = harness.client();
    let run = client.submit(&spec(7, JobSpec::DEFAULT_PRIORITY)).unwrap();
    client.await_done(run).unwrap();
    drop(client);
    harness.join().unwrap();
    fs::read(path).unwrap()
}

/// Byte offsets where each record ends (the first entry is the end of the
/// magic — a zero-record journal).
fn record_bounds(bytes: &[u8]) -> Vec<usize> {
    let mut bounds = vec![8usize];
    let mut pos = 8;
    while pos < bytes.len() {
        let len = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 8 + len;
        bounds.push(pos);
    }
    assert_eq!(pos, bytes.len(), "journal must end on a record boundary");
    bounds
}

/// Truncating the file at *every* byte offset — the only damage a crash
/// can legitimately inflict — recovers cleanly to the longest
/// whole-record prefix, with `torn` flagged exactly when the cut is not
/// on a record boundary (mirrors the `properties.rs` truncation sweeps).
#[test]
fn truncation_at_every_offset_recovers_the_prefix() {
    let path = temp_path("torn");
    let bytes = small_journal(&path);
    let bounds = record_bounds(&bytes);
    let full = recover(&path).unwrap();
    assert!(full.records.len() >= 8, "mix too small to be interesting");

    let cut = temp_path("torn-cut");
    for off in 0..bytes.len() {
        fs::write(&cut, &bytes[..off]).unwrap();
        let rec = recover(&cut).unwrap_or_else(|e| {
            panic!("cut at byte {off} must recover cleanly, got: {e:#}")
        });
        let whole = bounds.iter().filter(|&&b| b <= off).count().saturating_sub(1);
        assert_eq!(rec.records.len(), whole, "records after a cut at byte {off}");
        assert_eq!(
            rec.records.as_slice(),
            &full.records[..whole],
            "the surviving prefix is bit-identical (cut at byte {off})"
        );
        let boundary = off == 0 || bounds.contains(&off);
        assert_eq!(rec.torn, !boundary, "torn flag for a cut at byte {off}");
    }
    let _ = fs::remove_file(&path);
    let _ = fs::remove_file(&cut);
}

/// Interior damage is *not* a crash artifact — a flipped byte or foreign
/// header means the disk or an operator lied, and recovery must refuse
/// loudly, naming the record and byte offset, rather than silently
/// resurrecting half a history.
#[test]
fn interior_corruption_fails_loudly_with_the_offset() {
    let path = temp_path("corrupt");
    let bytes = small_journal(&path);
    let bounds = record_bounds(&bytes);
    let mangled = temp_path("corrupt-mangled");

    // bad magic
    let mut bad = bytes.clone();
    bad[0] ^= 0xFF;
    fs::write(&mangled, &bad).unwrap();
    let msg = format!("{:#}", recover(&mangled).unwrap_err());
    assert!(msg.contains("bad journal magic at byte offset 0"), "{msg}");

    // a flipped payload byte in an interior record: CRC catches it and the
    // error names exactly which record at which offset
    for rec_idx in [0, full_midpoint(&bounds)] {
        let start = bounds[rec_idx];
        let mut bad = bytes.clone();
        bad[start + 8] ^= 0xFF; // first payload byte of that record
        fs::write(&mangled, &bad).unwrap();
        let msg = format!("{:#}", recover(&mangled).unwrap_err());
        assert!(
            msg.contains(&format!("CRC mismatch in record {rec_idx} at byte offset {start}")),
            "record {rec_idx}: {msg}"
        );
    }

    // an absurd length field mid-file is corruption, not a torn tail
    let start = bounds[1];
    let mut bad = bytes.clone();
    bad[start..start + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    fs::write(&mangled, &bad).unwrap();
    let msg = format!("{:#}", recover(&mangled).unwrap_err());
    assert!(
        msg.contains(&format!("record 1 at byte offset {start}")),
        "length-field corruption: {msg}"
    );

    let _ = fs::remove_file(&path);
    let _ = fs::remove_file(&mangled);
}

/// An interior record index, away from both ends.
fn full_midpoint(bounds: &[usize]) -> usize {
    (bounds.len().saturating_sub(1)) / 2
}
