//! Cross-module integration: the paper's central claims on small inputs.
//!
//! These are the "does the system reproduce the paper's *shape*" tests:
//! distributed ≈ non-distributed accuracy across D1/D2/D3, both DMLs, all
//! backends; communication stays tiny; multi-site runs stay consistent.

use dsc::config::{Backend, PipelineConfig};
use dsc::coordinator::run_pipeline;
use dsc::data::scenario::{self, Scenario};
use dsc::data::{gmm, iris, uci_proxy};
use dsc::dml::DmlKind;
use dsc::spectral::{Algo, Bandwidth};

fn nondistributed(ds: &dsc::data::Dataset) -> Vec<scenario::SitePart> {
    vec![scenario::SitePart {
        site_id: 0,
        data: ds.clone(),
        global_idx: (0..ds.len() as u32).collect(),
    }]
}

fn cfg_for(k: usize, codes: usize, seed: u64) -> PipelineConfig {
    PipelineConfig {
        total_codes: codes,
        k_clusters: k,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed,
        ..Default::default()
    }
}

/// The paper's core claim, miniaturized: on the 10-D mixture, the
/// distributed accuracy is within a small gap of non-distributed for every
/// scenario and both DMLs.
#[test]
fn distributed_matches_nondistributed_10d_mixture() {
    let ds = gmm::paper_mixture_10d(8_000, 0.3, 41);
    let k = 4;
    let codes = 200; // 40:1, the paper's ratio

    // rpTrees codewords are coarser at equal compression, so their floor is
    // lower — exactly the Fig. 6 vs Fig. 7 relationship in the paper.
    for (dml, floor) in [(DmlKind::KMeans, 0.75), (DmlKind::RpTree, 0.68)] {
        let mut cfg = cfg_for(k, codes, 5);
        cfg.dml = dml;
        let base = run_pipeline(&nondistributed(&ds), &cfg).unwrap();
        assert!(base.accuracy > floor, "{dml}: baseline accuracy {}", base.accuracy);

        for sc in [Scenario::D1, Scenario::D2, Scenario::D3] {
            let parts = scenario::split(&ds, sc, 2, 13);
            let dist = run_pipeline(&parts, &cfg).unwrap();
            let gap = base.accuracy - dist.accuracy;
            assert!(
                gap < 0.08,
                "{dml} {sc}: distributed {:.4} vs baseline {:.4}",
                dist.accuracy,
                base.accuracy
            );
        }
    }
}

#[test]
fn communication_is_codewords_only() {
    let ds = gmm::paper_mixture_10d(8_000, 0.3, 43);
    let parts = scenario::split(&ds, Scenario::D3, 2, 17);
    let cfg = cfg_for(4, 200, 7);
    let report = run_pipeline(&parts, &cfg).unwrap();

    // wire bytes ≈ codewords (f32·dim + u32 weight) + label frames + headers
    let payload = report.n_codes as u64 * (10 * 4 + 4);
    assert!(report.net.total_bytes() >= payload);
    assert!(
        report.net.total_bytes() < payload + 4096,
        "unexpected wire overhead: {} vs payload {payload}",
        report.net.total_bytes()
    );
    // compression ratio ~ dataset_bytes / codeword_bytes (≫ 10×)
    assert!(report.full_data_bytes > 20 * report.net.total_bytes());
}

#[test]
fn all_backends_agree_on_easy_data() {
    let comps = vec![
        gmm::Component::isotropic(vec![0.0, 0.0, 0.0], 0.5, 1.0),
        gmm::Component::isotropic(vec![10.0, 0.0, 0.0], 0.5, 1.0),
        gmm::Component::isotropic(vec![0.0, 10.0, 0.0], 0.5, 1.0),
    ];
    let ds = gmm::sample("3blobs", &comps, 3_000, 47);
    let parts = scenario::split(&ds, Scenario::D2, 2, 19);

    let has_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    let backends: &[Backend] = if has_artifacts {
        &[Backend::Native, Backend::Xla, Backend::XlaFull]
    } else {
        eprintln!("SKIP xla backends: artifacts missing");
        &[Backend::Native]
    };
    for &backend in backends {
        let cfg = PipelineConfig { backend, ..cfg_for(3, 96, 11) };
        let report = run_pipeline(&parts, &cfg).unwrap();
        assert!(
            report.accuracy > 0.99,
            "{backend:?}: accuracy {}",
            report.accuracy
        );
    }
}

#[test]
fn iris_end_to_end() {
    // the real-data pocket test: 150 points, 2 sites, 3 clusters
    let ds = iris::load();
    let parts = scenario::split(&ds, Scenario::D3, 2, 3);
    let cfg = PipelineConfig {
        total_codes: 40,
        k_clusters: 3,
        algo: Algo::Njw,
        bandwidth: Bandwidth::EigengapSearch { k: 3 },
        seed: 5,
        ..Default::default()
    };
    let report = run_pipeline(&parts, &cfg).unwrap();
    // spectral clustering of iris typically lands 0.83–0.97 depending on σ
    assert!(report.accuracy > 0.80, "iris accuracy {}", report.accuracy);
}

#[test]
fn multisite_accuracy_stays_flat() {
    // Table 6's shape: more sites must not degrade accuracy materially
    let spec = uci_proxy::by_name("hepmass").unwrap();
    let ds = spec.generate(8_000, 51);
    let mut cfg = cfg_for(2, 300, 13);
    cfg.bandwidth = Bandwidth::MedianScale(0.75);

    let base = run_pipeline(&nondistributed(&ds), &cfg).unwrap();
    for sites in [2, 3, 4] {
        let parts = scenario::split(&ds, Scenario::D2, sites, 23);
        let report = run_pipeline(&parts, &cfg).unwrap();
        assert!(
            (base.accuracy - report.accuracy).abs() < 0.08,
            "{sites} sites: {:.4} vs base {:.4}",
            report.accuracy,
            base.accuracy
        );
        assert_eq!(report.site_dml.len(), sites);
    }
}

#[test]
fn elapsed_model_components_add_up() {
    let ds = gmm::paper_mixture_10d(4_000, 0.1, 53);
    let parts = scenario::split(&ds, Scenario::D3, 2, 29);
    let cfg = cfg_for(4, 128, 17);
    let r = run_pipeline(&parts, &cfg).unwrap();
    let max_dml = r.site_dml.iter().copied().max().unwrap();
    assert_eq!(r.elapsed_model, max_dml + r.central + r.populate);
    // modeled elapsed uses max-over-sites, so it is ≤ wall + slack and
    // strictly less than the sum of all site timings for 2+ busy sites
    let sum_dml: std::time::Duration = r.site_dml.iter().sum();
    assert!(sum_dml >= max_dml);
}

#[test]
fn weighted_affinity_ablation_runs() {
    let ds = gmm::paper_mixture_10d(4_000, 0.3, 59);
    let parts = scenario::split(&ds, Scenario::D1, 2, 31);
    let mut cfg = cfg_for(4, 128, 19);
    cfg.weighted_affinity = true;
    let report = run_pipeline(&parts, &cfg).unwrap();
    assert!(report.accuracy > 0.70, "weighted accuracy {}", report.accuracy);
}

#[test]
fn uci_proxy_two_class_rows_behave() {
    // one easy (skinseg) and one hard (hepmass) Table-3 row, miniaturized
    for (name, floor) in [("skinseg", 0.90), ("hepmass", 0.70)] {
        let spec = uci_proxy::by_name(name).unwrap();
        let ds = spec.generate(6_000, 61);
        let codes = spec.target_codewords().min(400);
        let mut cfg = cfg_for(spec.n_classes, codes, 23);
        cfg.bandwidth = Bandwidth::MedianScale(0.75);
        let base = run_pipeline(&nondistributed(&ds), &cfg).unwrap();
        let parts = scenario::split(&ds, Scenario::D2, 2, 37);
        let dist = run_pipeline(&parts, &cfg).unwrap();
        assert!(base.accuracy > floor, "{name} base {:.4}", base.accuracy);
        assert!(
            (base.accuracy - dist.accuracy).abs() < 0.08,
            "{name}: dist {:.4} vs base {:.4}",
            dist.accuracy,
            base.accuracy
        );
    }
}

#[test]
fn random_sample_baseline_works_but_quantizes_worse() {
    // A6: at the same communication budget, random landmarks still cluster
    // easy data, but with strictly worse quantization distortion.
    let ds = gmm::paper_mixture_10d(6_000, 0.3, 71);
    let parts = scenario::split(&ds, Scenario::D3, 2, 41);

    let mut cfg = cfg_for(4, 150, 29);
    cfg.dml = DmlKind::RandomSample;
    let sample_run = run_pipeline(&parts, &cfg).unwrap();
    cfg.dml = DmlKind::KMeans;
    let kmeans_run = run_pipeline(&parts, &cfg).unwrap();

    assert!(sample_run.accuracy > 0.70, "sample accuracy {}", sample_run.accuracy);
    for s in 0..2 {
        assert!(
            sample_run.site_distortion[s] > kmeans_run.site_distortion[s],
            "site {s}: sampling should quantize worse than Lloyd"
        );
    }
}

#[test]
fn dead_site_times_out_cleanly() {
    // failure injection: one site crashes before reporting; the leader must
    // return an error naming it within the collect timeout — and not hang.
    let ds = gmm::paper_mixture_10d(2_000, 0.3, 73);
    let parts = scenario::split(&ds, Scenario::D3, 3, 43);
    let cfg = PipelineConfig {
        total_codes: 64,
        k_clusters: 4,
        collect_timeout: std::time::Duration::from_millis(2_500),
        inject_site_failure: Some(1),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let err = run_pipeline(&parts, &cfg).expect_err("must fail");
    assert!(t0.elapsed() < std::time::Duration::from_secs(30), "did not time out promptly");
    let msg = format!("{err:#}");
    assert!(msg.contains("[1]"), "error should name the dead site: {msg}");
}

#[test]
fn all_sites_healthy_ignores_timeout_knob() {
    let ds = gmm::paper_mixture_10d(1_500, 0.3, 79);
    let parts = scenario::split(&ds, Scenario::D3, 2, 47);
    let cfg = PipelineConfig {
        total_codes: 48,
        k_clusters: 4,
        collect_timeout: std::time::Duration::from_secs(120),
        ..Default::default()
    };
    assert!(run_pipeline(&parts, &cfg).is_ok());
}
