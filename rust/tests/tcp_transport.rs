//! The transport seam, exercised over real loopback sockets in one process:
//! handshake, every message variant round-tripped, timeout firing, torn and
//! hostile frames, version/magic rejection — and the headline guarantee
//! that the channel and TCP backends produce identical labels and
//! byte-for-byte identical per-link counters for the same pipeline run.
//! (`examples/tcp_cluster.rs` re-proves parity with separate OS processes.)

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use dsc::config::PipelineConfig;
use dsc::coordinator::{run_leader_tcp, run_pipeline};
use dsc::data::scenario::{self, Scenario};
use dsc::data::gmm;
use dsc::dml::DmlKind;
use dsc::net::tcp::{connect_sites, SiteListener, TcpTimeouts};
use dsc::net::{LeaderNet, LinkSpec, Message, SiteNet};
use dsc::spectral::Bandwidth;

fn timeouts() -> TcpTimeouts {
    TcpTimeouts {
        connect: Duration::from_secs(5),
        io: Duration::from_secs(5),
        max_idle: Duration::ZERO,
    }
}

/// Bind a listener on an OS-assigned port and return it with its address.
fn listener() -> (SiteListener, String) {
    let l = SiteListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap().to_string();
    (l, addr)
}

#[test]
fn handshake_and_every_message_variant_roundtrips() {
    let (l, addr) = listener();

    let site_thread = std::thread::spawn(move || {
        let site = SiteNet::over(Box::new(l.accept(&timeouts()).unwrap()));
        assert_eq!(site.site_id(), 0);
        // echo every frame the leader sends back up, until Ack
        loop {
            let msg = site.recv().unwrap();
            let done = msg == Message::Ack;
            site.send(&msg).unwrap();
            if done {
                return;
            }
        }
    });

    let leader = LeaderNet::over(
        Box::new(connect_sites(&[addr], &timeouts()).unwrap()),
        LinkSpec::default(),
    );
    let variants = vec![
        Message::SiteInfo { site: 0, n_points: 12_000, dim: 10 },
        Message::DmlRequest {
            site: 0,
            dml: DmlKind::RpTree,
            target_codes: 300,
            max_iters: 30,
            tol: 1e-6,
            seed: 0xFEED_F00D,
        },
        Message::Codebook {
            site: 0,
            dim: 2,
            codewords: vec![1.0, -2.5, f32::MIN_POSITIVE, 4.0],
            weights: vec![7, 9],
        },
        Message::Labels { site: 0, labels: vec![0, 1, 2, 65535] },
        Message::Sigma(0.75),
        Message::Ack, // must be last: it ends the echo loop
    ];
    let mut expect_bytes = 0u64;
    for msg in &variants {
        leader.send(0, msg).unwrap();
        let (sid, echoed) = leader.recv().unwrap();
        assert_eq!(sid, 0);
        assert_eq!(&echoed, msg, "variant must survive the TCP roundtrip");
        expect_bytes += dsc::net::wire::encode(msg).len() as u64;
    }
    site_thread.join().unwrap();

    // accounting counts the encoded frames only — no TCP framing overhead
    let rep = leader.report();
    assert_eq!(rep.per_site[0].to_site.frames, variants.len() as u64);
    assert_eq!(rep.per_site[0].to_leader.frames, variants.len() as u64);
    assert_eq!(rep.per_site[0].to_site.bytes, expect_bytes);
    assert_eq!(rep.per_site[0].to_leader.bytes, expect_bytes);
}

#[test]
fn leader_recv_timeout_fires_on_silent_site() {
    let (l, addr) = listener();
    let site_thread = std::thread::spawn(move || {
        let site = SiteNet::over(Box::new(l.accept(&timeouts()).unwrap()));
        // stay connected but say nothing until the leader hangs up
        let _ = site.recv();
    });
    let leader = LeaderNet::over(
        Box::new(connect_sites(&[addr], &timeouts()).unwrap()),
        LinkSpec::default(),
    );
    let t0 = Instant::now();
    let err = leader.recv_timeout(Duration::from_millis(100)).unwrap_err();
    assert!(t0.elapsed() < Duration::from_secs(5), "timeout did not fire promptly");
    assert!(err.to_string().contains("timed out"), "{err}");
    drop(leader); // closes the socket and unblocks the site thread
    site_thread.join().unwrap();
}

#[test]
fn torn_frame_is_rejected() {
    let (l, addr) = listener();
    // fake leader: honest handshake, then a frame that dies mid-payload
    let fake_leader = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        // hello: magic, version 1, role leader (0), site id 0
        let mut hello = Vec::new();
        hello.extend_from_slice(b"DSCP");
        hello.extend_from_slice(&1u16.to_le_bytes());
        hello.push(0);
        hello.extend_from_slice(&0u32.to_le_bytes());
        s.write_all(&hello).unwrap();
        let mut echo = [0u8; 11];
        s.read_exact(&mut echo).unwrap();
        // length prefix promises 100 bytes, only 10 arrive, then FIN
        s.write_all(&100u32.to_le_bytes()).unwrap();
        s.write_all(&[0u8; 10]).unwrap();
    });
    let site = SiteNet::over(Box::new(l.accept(&timeouts()).unwrap()));
    let err = site.recv().unwrap_err();
    assert!(err.to_string().contains("mid-frame"), "{err}");
    fake_leader.join().unwrap();
}

#[test]
fn hostile_length_prefix_is_rejected_without_allocation() {
    let (l, addr) = listener();
    let fake_leader = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut hello = Vec::new();
        hello.extend_from_slice(b"DSCP");
        hello.extend_from_slice(&1u16.to_le_bytes());
        hello.push(0);
        hello.extend_from_slice(&7u32.to_le_bytes());
        s.write_all(&hello).unwrap();
        let mut echo = [0u8; 11];
        s.read_exact(&mut echo).unwrap();
        s.write_all(&u32::MAX.to_le_bytes()).unwrap();
        // keep the socket open so only the length check can reject
        let mut sink = [0u8; 1];
        let _ = s.read(&mut sink);
    });
    let site = SiteNet::over(Box::new(l.accept(&timeouts()).unwrap()));
    assert_eq!(site.site_id(), 7, "site id comes from the leader's hello");
    let t0 = Instant::now();
    let err = site.recv().unwrap_err();
    assert!(err.to_string().contains("cap"), "{err}");
    assert!(t0.elapsed() < Duration::from_secs(5));
    drop(site); // closes the socket so the fake leader's blocking read ends
    fake_leader.join().unwrap();
}

#[test]
fn version_mismatch_is_rejected_by_the_site() {
    let (l, addr) = listener();
    let fake_leader = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut hello = Vec::new();
        hello.extend_from_slice(b"DSCP");
        hello.extend_from_slice(&99u16.to_le_bytes()); // future protocol
        hello.push(0);
        hello.extend_from_slice(&0u32.to_le_bytes());
        s.write_all(&hello).unwrap();
        // the site still answers with its own hello before hanging up, so a
        // mismatched peer learns which version this build speaks
        let mut echo = [0u8; 11];
        s.read_exact(&mut echo).unwrap();
        assert_eq!(&echo[..4], b"DSCP");
        assert_eq!(u16::from_le_bytes([echo[4], echo[5]]), dsc::net::tcp::PROTOCOL_VERSION);
    });
    let err = l.accept(&timeouts()).unwrap_err();
    assert!(err.to_string().contains("version mismatch"), "{err}");
    fake_leader.join().unwrap();
}

#[test]
fn garbage_magic_is_rejected() {
    let (l, addr) = listener();
    let fake_leader = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET / HTTP/1.1\r\n").unwrap();
        let mut sink = [0u8; 64];
        let _ = s.read(&mut sink);
    });
    let err = l.accept(&timeouts()).unwrap_err();
    assert!(format!("{err:#}").contains("magic"), "{err:#}");
    fake_leader.join().unwrap();
}

#[test]
fn version_mismatch_is_rejected_by_the_leader() {
    // a fake *site* speaking a future protocol version
    let raw = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = raw.local_addr().unwrap().to_string();
    let fake_site = std::thread::spawn(move || {
        let (mut s, _) = raw.accept().unwrap();
        let mut leader_hello = [0u8; 11];
        s.read_exact(&mut leader_hello).unwrap();
        let mut hello = Vec::new();
        hello.extend_from_slice(b"DSCP");
        hello.extend_from_slice(&99u16.to_le_bytes());
        hello.push(1); // role: site
        hello.extend_from_slice(&leader_hello[7..11]); // echo the id
        s.write_all(&hello).unwrap();
    });
    let err = connect_sites(&[addr], &timeouts()).unwrap_err();
    assert!(format!("{err:#}").contains("version mismatch"), "{err:#}");
    fake_site.join().unwrap();
}

/// The headline guarantee: same data, same config, same seed ⇒ the channel
/// star and a real TCP star produce identical labels and identical
/// per-link byte counters.
#[test]
fn channel_and_tcp_backends_are_byte_and_label_identical() {
    let ds = gmm::paper_mixture_10d(3_000, 0.1, 21);
    let parts = scenario::split(&ds, Scenario::D3, 2, 21);
    let cfg = PipelineConfig {
        total_codes: 96,
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed: 21,
        ..Default::default()
    };

    let base = run_pipeline(&parts, &cfg).unwrap();

    // TCP star inside this process: one thread per site over loopback.
    let mut cfg_tcp = cfg.clone();
    let mut listeners = Vec::new();
    for _ in 0..parts.len() {
        let (l, addr) = listener();
        listeners.push(l);
        cfg_tcp.net.sites.push(addr);
    }

    let (tcp_report, site_outcomes) = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (l, part) in listeners.into_iter().zip(&parts) {
            handles.push(scope.spawn(move || {
                let net = SiteNet::over(Box::new(l.accept(&timeouts()).unwrap()));
                assert_eq!(net.site_id(), part.site_id);
                dsc::site::serve(&net, &part.data).unwrap()
            }));
        }
        let report = run_leader_tcp(&cfg_tcp).unwrap();
        let outcomes: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        (report, outcomes)
    });

    // labels: assemble the global vector exactly like run_pipeline does
    let mut tcp_labels = vec![0u16; ds.len()];
    for (part, out) in parts.iter().zip(&site_outcomes) {
        assert_eq!(out.labels.len(), part.data.len());
        for (local, &g) in part.global_idx.iter().enumerate() {
            tcp_labels[g as usize] = out.labels[local];
        }
    }
    assert_eq!(tcp_labels, base.labels, "labels must not depend on the transport");

    // counters: byte-for-byte identical per link and direction
    assert_eq!(tcp_report.net.per_site.len(), base.net.per_site.len());
    for (sid, (t, b)) in
        tcp_report.net.per_site.iter().zip(&base.net.per_site).enumerate()
    {
        assert_eq!(t.to_leader.frames, b.to_leader.frames, "site {sid} up frames");
        assert_eq!(t.to_leader.bytes, b.to_leader.bytes, "site {sid} up bytes");
        assert_eq!(t.to_site.frames, b.to_site.frames, "site {sid} down frames");
        assert_eq!(t.to_site.bytes, b.to_site.bytes, "site {sid} down bytes");
        assert_eq!(t.to_leader.sim_time, b.to_leader.sim_time, "site {sid} up sim time");
        assert_eq!(t.to_site.sim_time, b.to_site.sim_time, "site {sid} down sim time");
    }
    assert_eq!(tcp_report.net.total_bytes(), base.net.total_bytes());
    assert_eq!(tcp_report.outcome.n_codes, base.n_codes);
    assert_eq!(tcp_report.outcome.sigma, base.sigma);
    assert_eq!(tcp_report.outcome.site_points.iter().sum::<u64>(), ds.len() as u64);
}

/// `[net] max_idle_secs`: an accepted connection with no frame at all for
/// longer than the limit is declared dead (silent-leader-death detection),
/// while a connection with traffic inside the window stays healthy.
#[test]
fn max_idle_drops_a_silent_leader() {
    let (l, addr) = listener();
    let fake_leader = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut hello = Vec::new();
        hello.extend_from_slice(b"DSCP");
        hello.extend_from_slice(&1u16.to_le_bytes());
        hello.push(0); // classic leader role
        hello.extend_from_slice(&0u32.to_le_bytes());
        s.write_all(&hello).unwrap();
        let mut echo = [0u8; 11];
        s.read_exact(&mut echo).unwrap();
        // say nothing, but keep the socket open: only the idle deadline
        // can reject this
        let mut sink = [0u8; 1];
        let _ = s.read(&mut sink);
    });
    let t = TcpTimeouts {
        connect: Duration::from_secs(5),
        io: Duration::from_secs(5),
        max_idle: Duration::from_millis(200),
    };
    let site = SiteNet::over(Box::new(l.accept(&t).unwrap()));
    let t0 = Instant::now();
    let err = site.recv().unwrap_err();
    assert!(format!("{err:#}").contains("idle"), "{err:#}");
    let waited = t0.elapsed();
    assert!(waited >= Duration::from_millis(200), "fired early: {waited:?}");
    assert!(waited < Duration::from_secs(4), "fired late: {waited:?}");
    drop(site);
    fake_leader.join().unwrap();
}

/// A frame arriving inside the idle window resets nothing fatal: the site
/// still reads it fine with `max_idle` armed.
#[test]
fn max_idle_tolerates_traffic_within_the_window() {
    let (l, addr) = listener();
    let fake_leader = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut hello = Vec::new();
        hello.extend_from_slice(b"DSCP");
        hello.extend_from_slice(&1u16.to_le_bytes());
        hello.push(0);
        hello.extend_from_slice(&0u32.to_le_bytes());
        s.write_all(&hello).unwrap();
        let mut echo = [0u8; 11];
        s.read_exact(&mut echo).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        // one ACK frame, well inside the 500 ms idle window
        let frame = dsc::net::wire::encode(&Message::Ack);
        s.write_all(&(frame.len() as u32).to_le_bytes()).unwrap();
        s.write_all(&frame).unwrap();
    });
    let t = TcpTimeouts {
        connect: Duration::from_secs(5),
        io: Duration::from_secs(5),
        max_idle: Duration::from_millis(500),
    };
    let site = SiteNet::over(Box::new(l.accept(&t).unwrap()));
    assert_eq!(site.recv().unwrap(), Message::Ack);
    fake_leader.join().unwrap();
}

/// The handshake role selects the site dialect: role 3 (job-serving
/// leader) opens a session, role 0 a classic one-shot run, and a client
/// role is turned away with advice.
#[test]
fn hello_roles_select_the_site_dialect() {
    for (role, expect_session) in [(0u8, false), (3u8, true)] {
        let (l, addr) = listener();
        let fake_leader = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let mut hello = Vec::new();
            hello.extend_from_slice(b"DSCP");
            hello.extend_from_slice(&1u16.to_le_bytes());
            hello.push(role);
            hello.extend_from_slice(&4u32.to_le_bytes());
            s.write_all(&hello).unwrap();
            let mut echo = [0u8; 11];
            s.read_exact(&mut echo).unwrap();
        });
        let t = l.accept(&timeouts()).unwrap();
        assert_eq!(t.session_mode(), expect_session, "role {role}");
        fake_leader.join().unwrap();
    }

    // a client dialing a site is refused with a pointer to --serve
    let (l, addr) = listener();
    let fake_client = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut hello = Vec::new();
        hello.extend_from_slice(b"DSCP");
        hello.extend_from_slice(&1u16.to_le_bytes());
        hello.push(2); // client role
        hello.extend_from_slice(&0u32.to_le_bytes());
        s.write_all(&hello).unwrap();
        let mut echo = [0u8; 11];
        s.read_exact(&mut echo).unwrap();
    });
    let err = l.accept(&timeouts()).unwrap_err();
    assert!(format!("{err:#}").contains("--serve"), "{err:#}");
    fake_client.join().unwrap();
}

/// A site daemon loop survives a leader that connects and immediately
/// vanishes (the `dsc site` daemon uses the same accept + serve pieces).
#[test]
fn site_survives_leader_that_disconnects_early() {
    let (l, addr) = listener();
    let fake_leader = std::thread::spawn(move || {
        let mut s = TcpStream::connect(addr).unwrap();
        let mut hello = Vec::new();
        hello.extend_from_slice(b"DSCP");
        hello.extend_from_slice(&1u16.to_le_bytes());
        hello.push(0);
        hello.extend_from_slice(&0u32.to_le_bytes());
        s.write_all(&hello).unwrap();
        let mut echo = [0u8; 11];
        s.read_exact(&mut echo).unwrap();
        // hang up without a single protocol frame
    });
    let site = SiteNet::over(Box::new(l.accept(&timeouts()).unwrap()));
    let ds = gmm::paper_mixture_2d(100, 3);
    // The exact failure point races (the registration send may still land
    // in the kernel buffer, or already see a reset); the contract is only
    // that serve errors out instead of hanging or panicking.
    assert!(dsc::site::serve(&site, &ds).is_err());
    fake_leader.join().unwrap();
}
