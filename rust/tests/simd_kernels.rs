//! SIMD kernel parity suite — the forced-off matrix for the kernel layer
//! (`linalg::kernels`).
//!
//! The kernels promise **bit parity by construction** between the scalar
//! arm and whatever arm runtime dispatch selects (AVX2 on capable x86_64,
//! scalar everywhere else): same lane-structured accumulators, same
//! shuffle-mirroring reduction trees, mul-then-add with no FMA
//! contraction. This suite is the enforcement:
//!
//! * a sweep of every kernel over lengths 0..=67 — covering the empty
//!   case, sub-lane lengths, exact lane multiples, and every tail residue
//!   of both the 4-wide f64 and 8-wide f32 paths — plus deliberately
//!   unaligned slices (offset 1..3 into a larger buffer, which `loadu`
//!   must not care about but an aligned-load bug would);
//! * an end-to-end pin: `run_pipeline` on the quickstart GMM under
//!   `DSC_SIMD=scalar` and under runtime dispatch must produce identical
//!   labels, accuracy bits, and byte counters.
//!
//! The dispatch mode is process-global, so every test that flips it holds
//! `MODE_LOCK` and restores `Auto` on exit; the sweep tests compare the
//! *dispatched* entry points against the explicit `kernels::scalar` arm,
//! which exercises AVX2-vs-scalar parity exactly on the hardware that has
//! AVX2 and degenerates to scalar-vs-scalar (trivially green) elsewhere.

use std::sync::Mutex;

use dsc::config::PipelineConfig;
use dsc::coordinator::run_pipeline;
use dsc::data::gmm;
use dsc::data::scenario::{self, Scenario};
use dsc::linalg::kernels::{self, scalar, SimdMode};
use dsc::spectral::Bandwidth;

/// Serializes tests that touch the process-global dispatch mode. Poison is
/// ignored — a failed parity test must not cascade into lock panics.
static MODE_LOCK: Mutex<()> = Mutex::new(());

/// Deterministic, sign-varied f32 pattern with enough mantissa variety
/// that any reduction-order difference shows up in the low bits.
fn pat(len: usize, salt: u32) -> Vec<f32> {
    (0..len)
        .map(|i| {
            let h = (i as u32).wrapping_mul(2_654_435_761).wrapping_add(salt.wrapping_mul(97));
            ((h % 8000) as f32 - 4000.0) / 321.0
        })
        .collect()
}

fn pat_f64(len: usize, salt: u32) -> Vec<f64> {
    pat(len, salt).iter().map(|&v| v as f64 * 1.0625).collect()
}

/// Sweep every kernel over 0..=67 with the dispatched arm pinned to Auto.
#[test]
fn kernel_sweep_dispatched_matches_scalar_bitwise() {
    let _guard = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    kernels::set_mode(SimdMode::Auto);

    for len in 0..=67usize {
        let a = pat(len, 1);
        let b = pat(len, 2);
        let z = pat_f64(len, 3);

        assert_eq!(
            kernels::dot_f32(&a, &b).to_bits(),
            scalar::dot_f32(&a, &b).to_bits(),
            "dot_f32 len {len}"
        );
        assert_eq!(
            kernels::dot_f32_f64(&a, &z).to_bits(),
            scalar::dot_f32_f64(&a, &z).to_bits(),
            "dot_f32_f64 len {len}"
        );
        assert_eq!(
            kernels::sqdist_f32(&a, &b).to_bits(),
            scalar::sqdist_f32(&a, &b).to_bits(),
            "sqdist_f32 len {len}"
        );

        // gather: scrambled but in-bounds columns over a z larger than the
        // row, like a real CSR row
        let zbig = pat_f64(len.max(1) * 3 + 5, 4);
        let cols: Vec<u32> =
            (0..len).map(|i| ((i * 29 + 11) % zbig.len()) as u32).collect();
        assert_eq!(
            kernels::spmv_row_f64(&a, &cols, &zbig).to_bits(),
            scalar::spmv_row_f64(&a, &cols, &zbig).to_bits(),
            "spmv_row_f64 len {len}"
        );

        let mut o1 = pat(len, 5);
        let mut o2 = o1.clone();
        kernels::axpy_f32(&mut o1, -2.625, &b);
        scalar::axpy_f32(&mut o2, -2.625, &b);
        assert_eq!(
            o1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            o2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "axpy_f32 len {len}"
        );
    }
}

/// Same sweep on unaligned slices: every input starts 1–3 floats into a
/// larger buffer, so a kernel that assumed 16/32-byte alignment would
/// fault or read the wrong lanes.
#[test]
fn kernel_sweep_survives_unaligned_slices() {
    let _guard = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    kernels::set_mode(SimdMode::Auto);

    for len in [1usize, 4, 7, 8, 9, 15, 16, 17, 31, 33, 64, 67] {
        for off in 1..=3usize {
            let abuf = pat(len + off, 6);
            let bbuf = pat(len + off, 7);
            let zbuf = pat_f64(len + off, 8);
            let (a, b, z) = (&abuf[off..], &bbuf[off..], &zbuf[off..]);

            assert_eq!(
                kernels::dot_f32(a, b).to_bits(),
                scalar::dot_f32(a, b).to_bits(),
                "dot_f32 len {len} off {off}"
            );
            assert_eq!(
                kernels::dot_f32_f64(a, z).to_bits(),
                scalar::dot_f32_f64(a, z).to_bits(),
                "dot_f32_f64 len {len} off {off}"
            );
            assert_eq!(
                kernels::sqdist_f32(a, b).to_bits(),
                scalar::sqdist_f32(a, b).to_bits(),
                "sqdist_f32 len {len} off {off}"
            );

            let zbig = pat_f64(len * 2 + 9, 9);
            let colbuf: Vec<u32> =
                (0..len + off).map(|i| ((i * 13 + 3) % zbig.len()) as u32).collect();
            let cols = &colbuf[off..];
            assert_eq!(
                kernels::spmv_row_f64(a, cols, &zbig).to_bits(),
                scalar::spmv_row_f64(a, cols, &zbig).to_bits(),
                "spmv_row_f64 len {len} off {off}"
            );

            let mut o1 = pat(len, 10);
            let mut o2 = o1.clone();
            kernels::axpy_f32(&mut o1, 0.8125, b);
            scalar::axpy_f32(&mut o2, 0.8125, b);
            assert_eq!(
                o1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                o2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "axpy_f32 len {len} off {off}"
            );
        }
    }
}

/// Hostile values the tails and reduction trees must not mishandle:
/// infinities, zeros of both signs, denormal-adjacent magnitudes.
#[test]
fn kernel_sweep_special_values() {
    let _guard = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    kernels::set_mode(SimdMode::Auto);

    let specials: Vec<f32> = vec![
        0.0,
        -0.0,
        1.0,
        -1.0,
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE,
        1e30,
        -1e30,
        1e-30,
        3.5,
        -7.25,
        f32::MAX / 4.0,
        0.1,
    ];
    let b: Vec<f32> = specials.iter().rev().copied().collect();
    let z: Vec<f64> = specials.iter().map(|&v| v as f64).collect();

    assert_eq!(
        kernels::dot_f32(&specials, &b).to_bits(),
        scalar::dot_f32(&specials, &b).to_bits()
    );
    assert_eq!(
        kernels::dot_f32_f64(&specials, &z).to_bits(),
        scalar::dot_f32_f64(&specials, &z).to_bits()
    );
    assert_eq!(
        kernels::sqdist_f32(&specials, &b).to_bits(),
        scalar::sqdist_f32(&specials, &b).to_bits()
    );
}

/// The end-to-end pin: the full pipeline — DML, affinity, Lanczos, ncut,
/// label population — must not move a single bit between the forced-scalar
/// and dispatched kernel arms. This is the property that lets `DSC_SIMD`
/// default to `auto` without invalidating any recorded twin or journal.
#[test]
fn pipeline_labels_identical_scalar_vs_dispatched() {
    let _guard = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());

    let ds = gmm::paper_mixture_10d(6_000, 0.1, 7);
    let parts = scenario::split(&ds, Scenario::D3, 2, 7);
    let cfg = PipelineConfig {
        total_codes: 150,
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed: 7,
        ..Default::default()
    };

    kernels::set_mode(SimdMode::Scalar);
    let scalar_run = run_pipeline(&parts, &cfg).expect("scalar-arm pipeline");
    kernels::set_mode(SimdMode::Auto);
    let auto_run = run_pipeline(&parts, &cfg).expect("dispatched-arm pipeline");

    assert_eq!(scalar_run.labels, auto_run.labels, "labels diverged between kernel arms");
    assert_eq!(
        scalar_run.accuracy.to_bits(),
        auto_run.accuracy.to_bits(),
        "accuracy diverged between kernel arms"
    );
    assert_eq!(scalar_run.n_codes, auto_run.n_codes);
    assert_eq!(
        scalar_run.net.total_bytes(),
        auto_run.net.total_bytes(),
        "wire bytes diverged between kernel arms"
    );
    assert_eq!(scalar_run.sigma.to_bits(), auto_run.sigma.to_bits());
}

/// `DSC_SIMD` parsing contract (the env override mirrors `DSC_THREADS`).
#[test]
fn dsc_simd_values_parse() {
    assert_eq!(kernels::parse_mode("off"), Some(SimdMode::Scalar));
    assert_eq!(kernels::parse_mode("scalar"), Some(SimdMode::Scalar));
    assert_eq!(kernels::parse_mode("auto"), Some(SimdMode::Auto));
    assert_eq!(kernels::parse_mode("on"), Some(SimdMode::Auto));
    assert_eq!(kernels::parse_mode("sse9"), None);
    assert_eq!(kernels::parse_mode(""), None);
}
