//! PJRT execution tests: load real artifacts, run them, compare against the
//! native Rust implementations. Requires `make artifacts` to have run
//! (skipped with a message otherwise, so `cargo test` works on a clean
//! checkout too).

use dsc::data::gmm;
use dsc::rng::Rng;
use dsc::runtime::{default_artifact_dir, XlaRuntime};
use dsc::spectral::{affinity, njw};

fn runtime_or_skip() -> Option<XlaRuntime> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(XlaRuntime::new(dir).expect("runtime init"))
}

#[test]
fn embed_artifact_executes_and_is_orthonormal() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = gmm::paper_mixture_2d(200, 3);
    let w = vec![1.0f32; 200];
    let out = rt.embed(&ds.points, 2, &w, 1.5).expect("embed");
    assert_eq!(out.k_cols, 8);
    assert_eq!(out.evecs.len(), 200 * 8);
    assert_eq!(out.deg.len(), 200);
    assert!(out.deg.iter().all(|&d| d > 0.0));
    // top eigenvalue of M is 1
    assert!((out.evals[0] - 1.0).abs() < 1e-3, "λ1 = {}", out.evals[0]);
    // eigenvalues sorted descending
    for w in out.evals.windows(2) {
        assert!(w[0] >= w[1] - 1e-5);
    }
    // columns orthonormal over the padded domain; on the real rows they
    // remain near-orthonormal because pad rows are ~zero in the eigvecs
    for a in 0..8 {
        let norm: f32 = (0..200).map(|i| out.evecs[i * 8 + a].powi(2)).sum();
        assert!(norm <= 1.0 + 1e-3, "col {a} norm {norm}");
    }
}

#[test]
fn embed_artifact_matches_native_lanczos() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = gmm::paper_mixture_2d(180, 11);
    let w = vec![1.0f32; 180];
    let sigma = 1.2f32;

    let out = rt.embed(&ds.points, 2, &w, sigma).expect("embed");

    // native eigenvalues on the same affinity
    let aff = affinity::build(&ds.points, 2, &w, sigma as f64);
    let mut rng = Rng::new(5);
    let native_evals = njw::top_eigenvalues(&aff, 5, &mut rng);
    for j in 0..4 {
        assert!(
            (out.evals[j] as f64 - native_evals[j]).abs() < 5e-3,
            "eval {j}: xla {} vs native {}",
            out.evals[j],
            native_evals[j]
        );
    }

    // native degrees match artifact degrees
    for i in 0..180 {
        assert!(
            (out.deg[i] as f64 - aff.deg[i]).abs() < 1e-2 * aff.deg[i].max(1.0),
            "deg {i}: {} vs {}",
            out.deg[i],
            aff.deg[i]
        );
    }
}

#[test]
fn embed_then_kmeans_clusters_two_blobs() {
    let Some(rt) = runtime_or_skip() else { return };
    // two tight blobs; full XLA path: embed → row-normalize → Lloyd steps
    let mut pts = Vec::new();
    let mut rng = Rng::new(17);
    for _ in 0..100 {
        pts.push(rng.normal_f32(0.0, 0.3));
        pts.push(rng.normal_f32(0.0, 0.3));
    }
    for _ in 0..100 {
        pts.push(rng.normal_f32(8.0, 0.3));
        pts.push(rng.normal_f32(0.0, 0.3));
    }
    let w = vec![1.0f32; 200];
    let out = rt.embed(&pts, 2, &w, 1.0).expect("embed");

    // row-normalize first 2 columns into an 8-wide buffer for kstep
    let n = 200;
    let kd = out.k_cols;
    let mut rows = vec![0.0f32; n * kd];
    for i in 0..n {
        let src = &out.evecs[i * kd..i * kd + 2];
        let norm = (src[0] * src[0] + src[1] * src[1]).sqrt().max(1e-12);
        rows[i * kd] = src[0] / norm;
        rows[i * kd + 1] = src[1] / norm;
    }
    // init centroids from two points known to be in different blobs
    let mut c = vec![0.0f32; 2 * kd];
    c[..kd].copy_from_slice(&rows[..kd]);
    c[kd..].copy_from_slice(&rows[150 * kd..151 * kd]);

    let mut assign = vec![0i32; n];
    for _ in 0..10 {
        let (newc, idx, shift, _inertia) =
            rt.kmeans_step(&rows, kd, &c, 2).expect("kstep");
        c = newc;
        assign = idx;
        if shift < 1e-9 {
            break;
        }
    }
    let first: Vec<i32> = assign[..100].to_vec();
    let second: Vec<i32> = assign[100..].to_vec();
    assert!(first.iter().all(|&l| l == first[0]), "blob 1 split");
    assert!(second.iter().all(|&l| l == second[0]), "blob 2 split");
    assert_ne!(first[0], second[0]);
}

#[test]
fn executable_cache_reused_across_calls() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = gmm::paper_mixture_2d(50, 23);
    let w = vec![1.0f32; 50];
    assert_eq!(rt.cached_executables(), 0);
    rt.embed(&ds.points, 2, &w, 1.0).unwrap();
    assert_eq!(rt.cached_executables(), 1);
    rt.embed(&ds.points, 2, &w, 2.0).unwrap();
    assert_eq!(rt.cached_executables(), 1, "same bucket must reuse the executable");
}

#[test]
fn padding_is_invisible() {
    let Some(rt) = runtime_or_skip() else { return };
    // n=150 pads to 256; eigenvalues must match an exact-bucket run of the
    // same 150 points only (compare against native, which never pads)
    let ds = gmm::paper_mixture_2d(150, 29);
    let w = vec![1.0f32; 150];
    let out = rt.embed(&ds.points, 2, &w, 1.5).expect("embed");
    assert_eq!(out.bucket, "embed_n256_d4"); // 150×2 rounds up to 256×4

    let aff = affinity::build(&ds.points, 2, &w, 1.5);
    let mut rng = Rng::new(31);
    let native = njw::top_eigenvalues(&aff, 4, &mut rng);
    for j in 0..3 {
        assert!(
            (out.evals[j] as f64 - native[j]).abs() < 5e-3,
            "eval {j}: {} vs {}",
            out.evals[j],
            native[j]
        );
    }
}
