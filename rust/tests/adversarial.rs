//! The adversarial-tenant drill (`coordinator::loadgen::
//! run_adversarial_mix`): a flooder fires 20 submits at a leader with
//! per-client token-bucket admission on (burst 8), then two paying
//! tenants submit their budgets. The contract: the flood is clipped at
//! exactly the burst, every refusal carries the typed REJECT2
//! `RateLimited` code with the wait until the next token, and the paying
//! tenants' p99 sojourn stays within a small constant factor of the
//! flooder-free twin — DRR plus admission contains the blast radius.

use dsc::coordinator::loadgen::{run_adversarial_mix, AdversarialMix};
use dsc::net::RejectCode;

#[test]
fn flood_is_clipped_with_rate_limit_codes_and_paying_tenants_survive() {
    // ── the flooder-free twin: the baseline paying experience ────────────
    let quiet = run_adversarial_mix(&AdversarialMix::canonical(false)).unwrap();
    assert_eq!(quiet.flooder_accepted, 0);
    assert!(quiet.flooder_rejects.is_empty());
    assert_eq!((quiet.completed, quiet.rejected), (12, 0));
    assert_eq!(quiet.flooder.jobs, 0);
    assert_eq!(quiet.flooder.p99_ns, 0);

    // ── the flood ────────────────────────────────────────────────────────
    let flood = run_adversarial_mix(&AdversarialMix::canonical(true)).unwrap();

    // the bucket admits exactly the burst — the virtual clock is frozen
    // during the volley, so not one extra token drips in
    assert_eq!(flood.flooder_accepted, 8);
    assert_eq!(flood.flooder_rejects.len(), 12);
    for (i, &(code, detail)) in flood.flooder_rejects.iter().enumerate() {
        assert_eq!(code, RejectCode::RateLimited, "refusal {i} must be typed");
        assert!(detail > 0, "refusal {i} must carry the wait until the next token");
    }
    assert_eq!((flood.completed, flood.rejected), (20, 12));
    assert_eq!(flood.flooder.jobs, 8);

    // every admitted flood job is queued ahead of the paying tenants
    // (worst case), yet weighted fair queueing keeps each paying p99
    // within 3× of the flooder-free run
    for (p, q) in flood.paying.iter().zip(&quiet.paying) {
        assert_eq!((p.jobs, q.jobs), (6, 6));
        assert!(
            p.p99_ns <= 3 * q.p99_ns,
            "client {}: flooded p99 {} vs quiet p99 {}",
            p.client,
            p.p99_ns,
            q.p99_ns
        );
        assert!(
            p.mean_ns >= q.mean_ns,
            "client {}: a flood cannot improve paying latency",
            p.client
        );
    }
    // the flooder itself absorbs the spillover it created
    assert!(flood.flooder.p99_ns >= flood.paying[0].p99_ns);
    assert!(flood.flooder.p99_ns >= flood.paying[1].p99_ns);

    // weight-normalized fairness degrades under the flood (the flooder's
    // pre-backlog head start is real) but stays in a working band
    assert!(quiet.fairness > 0.95, "quiet fairness {}", quiet.fairness);
    assert!(flood.fairness > 0.6, "flooded fairness {}", flood.fairness);
    assert!(
        flood.fairness < quiet.fairness,
        "a flood that costs nothing would mean admission is doing DRR's job"
    );

    // determinism: the drill is a pure function of the mix, bit for bit
    let again = run_adversarial_mix(&AdversarialMix::canonical(true)).unwrap();
    assert_eq!(again, flood);
}
