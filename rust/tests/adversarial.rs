//! The adversarial-tenant drill (`coordinator::loadgen::
//! run_adversarial_mix`): a flooder fires 20 submits at a leader with
//! per-client token-bucket admission on (burst 8), then two paying
//! tenants submit their budgets. The contract: the flood is clipped at
//! exactly the burst, every refusal carries the typed REJECT2
//! `RateLimited` code with the wait until the next token, and the paying
//! tenants' p99 sojourn stays within a small constant factor of the
//! flooder-free twin — DRR plus admission contains the blast radius.

use std::sync::{Arc, Condvar, Mutex};

use dsc::config::PipelineConfig;
use dsc::coordinator::harness::{serve_channel, HarnessOpts};
use dsc::coordinator::loadgen::{run_adversarial_mix, AdversarialMix};
use dsc::coordinator::server::{ServerOpts, SubmitOutcome};
use dsc::coordinator::spec_from_config;
use dsc::data::{gmm, scenario, scenario::Scenario};
use dsc::net::RejectCode;
use dsc::spectral::Bandwidth;

#[test]
fn flood_is_clipped_with_rate_limit_codes_and_paying_tenants_survive() {
    // ── the flooder-free twin: the baseline paying experience ────────────
    let quiet = run_adversarial_mix(&AdversarialMix::canonical(false)).unwrap();
    assert_eq!(quiet.flooder_accepted, 0);
    assert!(quiet.flooder_rejects.is_empty());
    assert_eq!((quiet.completed, quiet.rejected), (12, 0));
    assert_eq!(quiet.flooder.jobs, 0);
    assert_eq!(quiet.flooder.p99_ns, 0);

    // ── the flood ────────────────────────────────────────────────────────
    let flood = run_adversarial_mix(&AdversarialMix::canonical(true)).unwrap();

    // the bucket admits exactly the burst — the virtual clock is frozen
    // during the volley, so not one extra token drips in
    assert_eq!(flood.flooder_accepted, 8);
    assert_eq!(flood.flooder_rejects.len(), 12);
    for (i, &(code, detail)) in flood.flooder_rejects.iter().enumerate() {
        assert_eq!(code, RejectCode::RateLimited, "refusal {i} must be typed");
        assert!(detail > 0, "refusal {i} must carry the wait until the next token");
    }
    assert_eq!((flood.completed, flood.rejected), (20, 12));
    assert_eq!(flood.flooder.jobs, 8);

    // every admitted flood job is queued ahead of the paying tenants
    // (worst case), yet weighted fair queueing keeps each paying p99
    // within 3× of the flooder-free run
    for (p, q) in flood.paying.iter().zip(&quiet.paying) {
        assert_eq!((p.jobs, q.jobs), (6, 6));
        assert!(
            p.p99_ns <= 3 * q.p99_ns,
            "client {}: flooded p99 {} vs quiet p99 {}",
            p.client,
            p.p99_ns,
            q.p99_ns
        );
        assert!(
            p.mean_ns >= q.mean_ns,
            "client {}: a flood cannot improve paying latency",
            p.client
        );
    }
    // the flooder itself absorbs the spillover it created
    assert!(flood.flooder.p99_ns >= flood.paying[0].p99_ns);
    assert!(flood.flooder.p99_ns >= flood.paying[1].p99_ns);

    // weight-normalized fairness degrades under the flood (the flooder's
    // pre-backlog head start is real) but stays in a working band
    assert!(quiet.fairness > 0.95, "quiet fairness {}", quiet.fairness);
    assert!(flood.fairness > 0.6, "flooded fairness {}", flood.fairness);
    assert!(
        flood.fairness < quiet.fairness,
        "a flood that costs nothing would mean admission is doing DRR's job"
    );

    // determinism: the drill is a pure function of the mix, bit for bit
    let again = run_adversarial_mix(&AdversarialMix::canonical(true)).unwrap();
    assert_eq!(again, flood);
}

/// A latch the central hook blocks on until the test opens it (and then
/// stays open for every later run).
#[derive(Default)]
struct Latch {
    open: Mutex<bool>,
    cv: Condvar,
}

impl Latch {
    fn enter_and_wait(&self) {
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.cv.wait(open).unwrap();
        }
    }

    fn open(&self) {
        *self.open.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

/// A queue-full storm must not rate-starve the tenant that paid for it:
/// a submit refused with `QueueFull` (or `BadSpec`) spent no server work,
/// so its admission token is refunded — only `RateLimited` refusals keep
/// the charge. Pre-fix, every storm reject burned a token, so a tenant
/// probing a briefly-full queue came back to find its own allowance gone.
#[test]
fn queue_full_storm_does_not_burn_admission_tokens() {
    let ds = gmm::paper_mixture_10d(400, 0.1, 51);
    let parts = scenario::split(&ds, Scenario::D3, 1, 51);
    let datasets: Vec<_> = parts.iter().map(|p| p.data.clone()).collect();
    let mut cfg = PipelineConfig {
        total_codes: 64,
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed: 51,
        ..Default::default()
    };
    // 4 tokens, no refill: the virtual clock is never advanced, so the
    // whole test runs on the initial burst — every charge is visible
    cfg.leader.admit_rate = 1.0;
    cfg.leader.admit_burst = 4;
    let spec = spec_from_config(&cfg);

    let latch = Arc::new(Latch::default());
    let hook = {
        let latch = Arc::clone(&latch);
        Arc::new(move |_run: u32| latch.enter_and_wait())
    };
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 1, // one queued job fills it
            allow_label_pull: false,
            central_workers: 1,
            client_limit: Some(1),
        },
        faults: Vec::new(),
        central_hook: Some(hook),
        hangups: vec![],
    };
    let mut harness = serve_channel(datasets, &cfg, opts).unwrap();
    let client = harness.client();

    // two tokens spent for real work: run 1 active (held at its central),
    // run 2 fills the depth-1 queue
    let run1 = client.submit(&spec).unwrap();
    let run2 = client.submit(&spec).unwrap();

    // the storm: five submits against the full queue. Every refusal must
    // be typed QueueFull — pre-fix the third one came back RateLimited,
    // because the first two storm rejects had silently burned the
    // tenant's remaining tokens
    for i in 0..5 {
        match client.try_submit_tracked(&spec).unwrap() {
            SubmitOutcome::Rejected { code: RejectCode::QueueFull, .. } => {}
            other => panic!("storm submit {i}: expected QueueFull, got {other:?}"),
        }
    }

    // drain, then spend the two remaining tokens on real work: both are
    // admitted, so the storm cost the tenant nothing
    latch.open();
    client.await_done(run1).unwrap();
    client.await_done(run2).unwrap();
    let run3 = client.submit(&spec).unwrap();
    let run4 = client.submit(&spec).unwrap();

    // the bucket is now genuinely empty, and a RateLimited refusal keeps
    // its charge — the meter still meters
    match client.try_submit_tracked(&spec).unwrap() {
        SubmitOutcome::Rejected { code: RejectCode::RateLimited, detail, .. } => {
            assert!(detail > 0, "RateLimited must carry the wait until the next token");
        }
        other => panic!("expected RateLimited on the empty bucket, got {other:?}"),
    }

    client.await_done(run3).unwrap();
    client.await_done(run4).unwrap();
    drop(client);
    let (stats, _) = harness.join().unwrap();
    assert_eq!(stats.completed, 4);
    assert_eq!(stats.rejected, 6, "5 QueueFull + 1 RateLimited");
}
