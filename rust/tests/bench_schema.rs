//! Schema lock on `BENCH_hotpath.json` — the recorded SIMD trajectory.
//!
//! The committed snapshot at the repo root and the file
//! `cargo bench --bench hotpath -- --json` writes must stay structurally
//! interchangeable: same top-level fields, same five kernel arms, same
//! per-arm fields, so trend tooling reading the artifact never has to
//! care which one it got. The writer lives in `benches/hotpath.rs`
//! (`ArmRecord::to_json` + `json_mode`); this test is its schema twin —
//! change one and the other must follow.
//!
//! By default the test checks the committed snapshot. CI points it at the
//! freshly measured file too (`DSC_BENCH_JSON=bench_out/BENCH_hotpath.json`),
//! so writer drift fails the build even though the snapshot is committed
//! from an authoring environment that may predate the change.

use std::collections::BTreeMap;
use std::path::PathBuf;

use dsc::runtime::json::{self, Value};

/// The five arms `json_mode` measures, in writer order.
const ARMS: &[&str] = &["assign", "affinity", "spmv_dense", "spmv_sparse", "lanczos"];

/// Top-level fields the writer emits. The committed placeholder may add
/// `note`; nothing else is allowed.
const TOP_FIELDS: &[&str] =
    &["bench", "executed", "threads", "cpu_features", "dispatched_arm", "throughput_unit"];

/// Per-arm fields, exactly as `ArmRecord::to_json` prints them.
const ARM_FIELDS: &[&str] = &[
    "config",
    "point_dims_per_run",
    "scalar_ms",
    "dispatched_ms",
    "throughput_scalar_pd_per_us",
    "throughput_dispatched_pd_per_us",
    "speedup",
    "parity",
];

fn object(v: &Value, what: &str) -> BTreeMap<String, Value> {
    match v {
        Value::Object(m) => m.clone(),
        other => panic!("{what} must be a JSON object, got {other:?}"),
    }
}

/// A measured file carries numbers; the committed placeholder is allowed
/// `null` until someone regenerates it on a machine with a toolchain.
fn check_number(v: &Value, executed: bool, what: &str) {
    match v {
        Value::Num(x) => assert!(x.is_finite(), "{what} must be finite, got {x}"),
        Value::Null => assert!(!executed, "{what} is null in a file claiming executed=true"),
        other => panic!("{what} must be a number{}, got {other:?}", if executed { "" } else { " or null" }),
    }
}

fn check_schema(text: &str, origin: &str) {
    let doc = json::parse(text).unwrap_or_else(|e| panic!("{origin}: not valid JSON: {e:#}"));
    let top = object(&doc, origin);

    let executed = match top.get("executed") {
        Some(Value::Bool(b)) => *b,
        other => panic!("{origin}: executed must be a bool, got {other:?}"),
    };

    // key inventory: writer fields + the five arms, `note` optional
    for key in TOP_FIELDS.iter().chain(ARMS) {
        assert!(top.contains_key(*key), "{origin}: missing top-level key {key:?}");
    }
    for key in top.keys() {
        let known = TOP_FIELDS.contains(&key.as_str())
            || ARMS.contains(&key.as_str())
            || key == "note";
        assert!(known, "{origin}: unexpected top-level key {key:?} — writer and schema diverged");
    }

    assert_eq!(top["bench"].as_str(), Some("hotpath"), "{origin}: bench tag");
    assert_eq!(
        top["throughput_unit"].as_str(),
        Some("point*dims/us"),
        "{origin}: throughput unit is part of the schema"
    );
    // threads / cpu_features / dispatched_arm name the hardware; a
    // measured file must fill them in
    check_number(&top["threads"], executed, &format!("{origin}: threads"));
    for key in ["cpu_features", "dispatched_arm"] {
        match &top[key] {
            Value::Str(s) => assert!(!s.is_empty(), "{origin}: {key} must be non-empty"),
            Value::Null => assert!(!executed, "{origin}: {key} null with executed=true"),
            other => panic!("{origin}: {key} must be a string or null, got {other:?}"),
        }
    }

    for arm in ARMS {
        let a = object(&top[*arm], &format!("{origin}: arm {arm}"));
        for key in ARM_FIELDS {
            assert!(a.contains_key(*key), "{origin}: arm {arm} missing {key:?}");
        }
        for key in a.keys() {
            assert!(
                ARM_FIELDS.contains(&key.as_str()),
                "{origin}: arm {arm} has unexpected key {key:?}"
            );
        }
        match &a["config"] {
            Value::Str(s) => assert!(!s.is_empty(), "{origin}: arm {arm} config"),
            other => panic!("{origin}: arm {arm} config must be a string, got {other:?}"),
        }
        assert_eq!(
            a["parity"].as_str(),
            Some("bit-identical"),
            "{origin}: arm {arm} — the bench refuses to write anything else"
        );
        for key in
            ["scalar_ms", "dispatched_ms", "throughput_scalar_pd_per_us",
             "throughput_dispatched_pd_per_us", "speedup"]
        {
            check_number(&a[key], executed, &format!("{origin}: arm {arm} {key}"));
        }
        // point_dims_per_run may be "(measured)"-dependent in the
        // placeholder (nnz is workload-derived), so null passes unexecuted
        check_number(&a["point_dims_per_run"], executed, &format!("{origin}: arm {arm} ops"));
    }
}

/// The committed repo-root snapshot always validates.
#[test]
fn committed_hotpath_snapshot_matches_the_writer_schema() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../BENCH_hotpath.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    check_schema(&text, "BENCH_hotpath.json (committed)");
}

/// CI sets `DSC_BENCH_JSON` to the file the bench just wrote, closing the
/// loop against the live writer; locally without the env var this is a
/// no-op.
#[test]
fn measured_hotpath_output_matches_the_writer_schema() {
    let Ok(path) = std::env::var("DSC_BENCH_JSON") else {
        return;
    };
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{path}: {e}"));
    check_schema(&text, "DSC_BENCH_JSON (measured)");
}
