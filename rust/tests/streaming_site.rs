//! Streaming-site acceptance: incremental ingest, shard-versioned DML
//! result caching, and the SITEINFO2 digest report.
//!
//! The contract under test (docs/PROTOCOL.md §"Shard digests",
//! docs/CONFIG.md `[site]`):
//!
//! * a repeat work order at an unchanged shard is answered from the DML
//!   result cache — **zero** DML passes, and the replayed codebook is
//!   bit-identical to a recompute, so labels and per-link byte counters
//!   are indistinguishable from a cache-off run;
//! * one ingested point moves the shard digest, which invalidates the
//!   cache; the post-ingest recompute equals a from-scratch build of the
//!   grown shard bit for bit (the incremental `fold_in` path only feeds
//!   the *live* codebook — cached results are never folded);
//! * `[site] report_digest` volunteers a SITEINFO2 frame per connection,
//!   observed by the leader but never accounted to any run.

mod common;

use common::pull_global;
use dsc::config::PipelineConfig;
use dsc::coordinator::harness::{serve_channel, HarnessOpts};
use dsc::coordinator::server::ServerOpts;
use dsc::coordinator::{run_pipeline, spec_from_config};
use dsc::data::scenario::{self, Scenario, SitePart};
use dsc::data::{gmm, Dataset};
use dsc::dml::{self, DmlKind, DmlParams};
use dsc::net::{star, LinkSpec, Message};
use dsc::site::{Session, SessionLimits};
use dsc::spectral::Bandwidth;

fn workload() -> (Dataset, Vec<SitePart>) {
    let ds = gmm::paper_mixture_10d(2_000, 0.1, 21);
    let parts = scenario::split(&ds, Scenario::D3, 2, 21);
    (ds, parts)
}

fn cfg() -> PipelineConfig {
    PipelineConfig {
        total_codes: 64,
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed: 21,
        ..Default::default()
    }
}

/// Run the same spec twice through one channel harness (sequentially —
/// `max_jobs = 1` — so the second work order arrives after the first
/// result is cached) and return per-job `(labels, per-site LinkReports)`
/// plus the per-site session outcomes.
fn twice_through_harness(
    parts: &[SitePart],
    cfg: &PipelineConfig,
) -> (Vec<(Vec<u16>, Vec<dsc::net::LinkReport>)>, Vec<dsc::site::SessionOutcome>) {
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 8,
            allow_label_pull: true,
            client_limit: Some(2),
            ..Default::default()
        },
        ..Default::default()
    };
    let datasets = parts.iter().map(|p| p.data.clone()).collect();
    let mut harness = serve_channel(datasets, cfg, opts).unwrap();
    let spec = spec_from_config(cfg);
    let clients = [harness.client(), harness.client()];
    let mut jobs = Vec::new();
    for client in &clients {
        let run = client.submit(&spec).unwrap();
        let report = client.await_done(run).unwrap();
        let labels = pull_global(client, run, &report, parts);
        jobs.push((labels, report.per_site));
    }
    drop(clients);
    let (stats, outcomes) = harness.join().unwrap();
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.failed, 0);
    (jobs, outcomes)
}

/// The headline: job 2 repeats job 1's spec against unchanged shards, so
/// every site answers it from the cache — zero DML passes — and nothing
/// downstream can tell: labels and per-run, per-link byte counters are
/// bit-identical, and both match the in-process pipeline.
#[test]
fn repeat_job_replays_the_cache_bit_identically() {
    let (_ds, parts) = workload();
    let base = run_pipeline(&parts, &cfg()).unwrap();

    let (jobs, outcomes) = twice_through_harness(&parts, &cfg());

    assert_eq!(jobs[0].0, base.labels, "job 1 vs pipeline");
    assert_eq!(jobs[1].0, jobs[0].0, "cached labels diverge from computed ones");
    assert_eq!(jobs[1].1, jobs[0].1, "cached byte counters diverge");

    for (site, o) in outcomes.iter().enumerate() {
        assert_eq!(o.runs_served, 2, "site {site} served both runs");
        assert_eq!(o.dml_passes, 1, "site {site}: the repeat must not recompute");
        assert_eq!(o.cache_hits, 1, "site {site}: the repeat must hit the cache");
    }
}

/// `[site] cache_dml = false` forces a full DML pass per work order — and
/// because DML is deterministic, the results are still identical, which is
/// exactly why the cache is safe to leave on by default.
#[test]
fn cache_off_recomputes_with_identical_results() {
    let (_ds, parts) = workload();
    let mut off = cfg();
    off.site.cache_dml = false;

    let (jobs_on, _) = twice_through_harness(&parts, &cfg());
    let (jobs_off, outcomes) = twice_through_harness(&parts, &off);

    for (site, o) in outcomes.iter().enumerate() {
        assert_eq!(o.dml_passes, 2, "site {site}: cache off must recompute each run");
        assert_eq!(o.cache_hits, 0, "site {site}: cache off must never hit");
    }
    assert_eq!(jobs_off[0].0, jobs_on[0].0, "labels depend on the cache setting");
    assert_eq!(jobs_off[1].1, jobs_on[1].1, "byte counters depend on the cache setting");
}

/// Drive one streaming [`Session`] by hand through two connections with an
/// ingest in between: the repeat inside connection 1 is a bit-identical
/// cache replay; the ingest moves the digest, and the first work order
/// after it recomputes — equal bit for bit to a from-scratch build over
/// the grown shard.
#[test]
fn ingest_flips_the_digest_and_the_cache_misses() {
    let ds = gmm::paper_mixture_2d(300, 9);
    let extra = gmm::paper_mixture_2d(20, 33);
    let params = DmlParams {
        kind: DmlKind::KMeans,
        target_codes: 8,
        max_iters: 10,
        tol: 1e-6,
        seed: 5,
    };
    let order = |run: u32| Message::RunDmlRequest {
        run,
        site: 0,
        dml: params.kind,
        target_codes: params.target_codes as u32,
        max_iters: params.max_iters as u32,
        tol: params.tol,
        seed: params.seed,
    };
    let codebook_of = |msg: Message| match msg {
        Message::RunCodebook { codewords, weights, .. } => (codewords, weights),
        other => panic!("expected a codebook, got {other:?}"),
    };

    let mut session = Session::new(ds.clone(), SessionLimits::default());
    let v0 = session.shard_version();

    // ── connection 1: the same work order twice ─────────────────────────
    let (leader, mut sites) = star(1, LinkSpec::default());
    let site_net = sites.remove(0);
    let outcome = std::thread::scope(|s| {
        let worker = s.spawn(|| session.serve(&site_net, None, |_| {}).unwrap());
        let mut books = Vec::new();
        for run in [1u32, 2] {
            leader.send(0, &order(run)).unwrap();
            books.push(codebook_of(leader.recv().unwrap().1));
        }
        assert_eq!(books[1], books[0], "cache replay must be bit-identical");
        drop(leader);
        worker.join().unwrap()
    });
    assert_eq!((outcome.dml_passes, outcome.cache_hits), (1, 1));

    // ── ingest between connections ──────────────────────────────────────
    assert_eq!(session.ingest(&extra).unwrap(), 20);
    assert_eq!(session.data().len(), 320);
    let v1 = session.shard_version();
    assert_ne!(v1, v0, "ingested points must move the shard version");
    // the live codebook was folded incrementally and still covers the shard
    let (live_params, live_cb) = session.live_codebook().expect("live codebook after a run");
    assert_eq!(live_params, &params);
    assert_eq!(live_cb.assign.len(), 320);
    live_cb.validate(320).unwrap();
    // an ingest of mismatched dimensionality is refused loudly
    let bad = gmm::paper_mixture_10d(5, 0.1, 1);
    assert!(session.ingest(&bad).is_err());
    assert_eq!(session.shard_version(), v1, "a refused ingest must not move the version");

    // ── connection 2: the cache is stale, the recompute is from scratch ──
    let expect = dml::apply(session.data(), &params);
    let (leader, mut sites) = star(1, LinkSpec::default());
    let site_net = sites.remove(0);
    let outcome = std::thread::scope(|s| {
        let worker = s.spawn(|| session.serve(&site_net, None, |_| {}).unwrap());
        leader.send(0, &order(3)).unwrap();
        let (codewords, weights) = codebook_of(leader.recv().unwrap().1);
        assert_eq!(codewords, expect.codewords, "post-ingest rebuild must be from scratch");
        assert_eq!(weights, expect.weights);
        assert_eq!(weights.iter().map(|&w| w as usize).sum::<usize>(), 320);
        // …and the repeat of *that* is a hit again
        leader.send(0, &order(4)).unwrap();
        let (cw2, _) = codebook_of(leader.recv().unwrap().1);
        assert_eq!(cw2, codewords);
        drop(leader);
        worker.join().unwrap()
    });
    assert_eq!((outcome.dml_passes, outcome.cache_hits), (1, 1));
    assert_eq!(session.dml_stats(), (2, 2), "cumulative counters span connections");
}

/// `[site] report_digest = true`: every site volunteers one SITEINFO2 at
/// session start. The leader records it (`ServerStats::digests_seen`) but
/// never accounts it to a run — per-link byte counters are identical to a
/// run with reporting off, and the legacy SITEINFO framing is untouched.
#[test]
fn digest_report_reaches_the_leader_without_touching_counters() {
    let (_ds, parts) = workload();
    let mut reporting = cfg();
    reporting.site.report_digest = true;

    let run_once = |cfg: &PipelineConfig| {
        let opts = HarnessOpts {
            server: ServerOpts {
                max_jobs: 1,
                queue_depth: 8,
                allow_label_pull: false,
                client_limit: Some(1),
                ..Default::default()
            },
            ..Default::default()
        };
        let datasets = parts.iter().map(|p| p.data.clone()).collect();
        let mut harness = serve_channel(datasets, cfg, opts).unwrap();
        let client = harness.client();
        let run = client.submit(&spec_from_config(cfg)).unwrap();
        let report = client.await_done(run).unwrap();
        drop(client);
        let (stats, _) = harness.join().unwrap();
        (report.per_site, stats)
    };

    let (quiet_links, quiet_stats) = run_once(&cfg());
    let (loud_links, loud_stats) = run_once(&reporting);

    assert_eq!(quiet_stats.digests_seen, 0);
    assert_eq!(loud_stats.digests_seen, parts.len() as u64, "one report per site");
    assert_eq!(
        loud_links, quiet_links,
        "a digest report must never be accounted to a run"
    );
}
