//! Property-based invariants across the whole stack (DESIGN.md §6),
//! using the in-crate `prop` harness.

use dsc::data::scenario::{self, Scenario};
use dsc::data::{gmm, Dataset};
use dsc::dml::{self, DmlKind, DmlParams};
use dsc::metrics::{adjusted_rand_index, clustering_accuracy, hungarian_max};
use dsc::prop::{forall, Gen};
use dsc::spectral::affinity;

fn random_dataset(g: &mut Gen, max_n: usize) -> Dataset {
    let n_classes = g.usize_in(1, 4);
    let dim = g.usize_in(1, 6);
    let n = g.usize_in(n_classes, max_n);
    let mut ds = Dataset::new("prop", dim, n_classes);
    for _ in 0..n {
        let label = g.usize_in(0, n_classes - 1) as u16;
        let coords = g.vec_f32(dim, -5.0, 5.0);
        ds.push(&coords, label);
    }
    ds
}

// ───────────────────────────── scenario splits ─────────────────────────────

#[test]
fn prop_splits_partition_the_data() {
    forall("splits conserve and never duplicate points", 40, 101, |g| {
        let ds = random_dataset(g, 400);
        let n_sites = g.usize_in(2, 4);
        let sc = [Scenario::D1, Scenario::D2, Scenario::D3][g.usize_in(0, 2)];
        let seed = g.rng().next_u64();
        let parts = scenario::split(&ds, sc, n_sites, seed);

        let total: usize = parts.iter().map(|p| p.data.len()).sum();
        if total != ds.len() {
            return Err(format!("{sc} lost points: {total} vs {}", ds.len()));
        }
        let mut seen = vec![false; ds.len()];
        for p in &parts {
            for (local, &g_idx) in p.global_idx.iter().enumerate() {
                if seen[g_idx as usize] {
                    return Err(format!("point {g_idx} duplicated"));
                }
                seen[g_idx as usize] = true;
                if p.data.point(local) != ds.point(g_idx as usize) {
                    return Err(format!("coords corrupted for {g_idx}"));
                }
                if p.data.labels[local] != ds.labels[g_idx as usize] {
                    return Err(format!("label corrupted for {g_idx}"));
                }
            }
        }
        if !seen.iter().all(|&b| b) {
            return Err("some points unassigned".into());
        }
        Ok(())
    });
}

// ───────────────────────────── codebooks ─────────────────────────────

#[test]
fn prop_codebooks_are_consistent() {
    forall("codebook weights sum to site size; assignments in range", 25, 202, |g| {
        let ds = random_dataset(g, 600);
        let kind = if g.bool(0.5) { DmlKind::KMeans } else { DmlKind::RpTree };
        let target = g.usize_in(1, 40);
        let params = DmlParams {
            kind,
            target_codes: target,
            max_iters: 10,
            tol: 1e-6,
            seed: g.rng().next_u64(),
        };
        let cb = dml::apply(&ds, &params);
        cb.validate(ds.len()).map_err(|e| format!("{kind}: {e}"))
    });
}

#[test]
fn prop_distortion_bounded_by_data_radius() {
    forall("quantization distortion ≤ max squared pairwise distance", 20, 203, |g| {
        let ds = random_dataset(g, 300);
        if ds.is_empty() {
            return Ok(());
        }
        let params = DmlParams {
            kind: DmlKind::KMeans,
            target_codes: g.usize_in(1, 20),
            max_iters: 8,
            tol: 1e-6,
            seed: 1,
        };
        let cb = dml::apply(&ds, &params);
        // coords live in [-5, 5]^dim ⇒ ‖x − q(x)‖² ≤ dim · 10²
        let bound = (ds.dim as f64) * 100.0;
        let d = cb.distortion(&ds);
        if d <= bound {
            Ok(())
        } else {
            Err(format!("distortion {d} exceeds bound {bound}"))
        }
    });
}

// ───────────────────────────── metrics ─────────────────────────────

#[test]
fn prop_accuracy_is_permutation_invariant() {
    forall("relabelling predictions never changes accuracy", 60, 304, |g| {
        let k = g.usize_in(1, 6);
        let n = g.usize_in(1, 200);
        let truth = g.labels(n, k);
        let pred = g.labels(n, k);
        let perm = g.permutation(k);
        let permuted: Vec<u16> = pred.iter().map(|&l| perm[l as usize] as u16).collect();
        let a = clustering_accuracy(&truth, &pred);
        let b = clustering_accuracy(&truth, &permuted);
        if (a - b).abs() < 1e-12 {
            Ok(())
        } else {
            Err(format!("{a} vs {b}"))
        }
    });
}

#[test]
fn prop_accuracy_bounds_and_perfection() {
    forall("accuracy ∈ [0, 1]; exact on identical labelings", 60, 305, |g| {
        let k = g.usize_in(1, 6);
        let n = g.usize_in(1, 200);
        let truth = g.labels(n, k);
        let acc_self = clustering_accuracy(&truth, &truth);
        if acc_self != 1.0 {
            return Err(format!("self-accuracy {acc_self}"));
        }
        let pred = g.labels(n, k);
        let acc = clustering_accuracy(&truth, &pred);
        if !(0.0..=1.0).contains(&acc) {
            return Err(format!("accuracy out of range: {acc}"));
        }
        Ok(())
    });
}

#[test]
fn prop_hungarian_at_least_greedy() {
    forall("hungarian ≥ greedy row assignment", 60, 306, |g| {
        let rows = g.usize_in(1, 7);
        let cols = g.usize_in(1, 7);
        let profit: Vec<Vec<f64>> = (0..rows)
            .map(|_| (0..cols).map(|_| g.f64_in(0.0, 100.0)).collect())
            .collect();
        let (best, _) = hungarian_max(&profit);
        // greedy: rows in order take their max still-free column
        let mut used = vec![false; cols];
        let mut greedy = 0.0;
        for row in &profit {
            let mut pick: Option<(usize, f64)> = None;
            for (c, &v) in row.iter().enumerate() {
                if !used[c] && pick.map_or(true, |(_, pv)| v > pv) {
                    pick = Some((c, v));
                }
            }
            if let Some((c, v)) = pick {
                used[c] = true;
                greedy += v;
            }
        }
        if best + 1e-9 >= greedy {
            Ok(())
        } else {
            Err(format!("hungarian {best} < greedy {greedy}"))
        }
    });
}

#[test]
fn prop_ari_agrees_on_perfect_match() {
    forall("ARI = 1 on labelings identical up to permutation", 40, 307, |g| {
        let k = g.usize_in(2, 5);
        let n = g.usize_in(k * 2, 150);
        let truth = g.labels(n, k);
        let perm = g.permutation(k);
        let relabeled: Vec<u16> = truth.iter().map(|&l| perm[l as usize] as u16).collect();
        let ari = adjusted_rand_index(&truth, &relabeled);
        if (ari - 1.0).abs() < 1e-9 {
            Ok(())
        } else {
            Err(format!("ARI {ari}"))
        }
    });
}

// ───────────────────────────── spectral invariants ─────────────────────────────

#[test]
fn prop_laplacian_spectrum_in_bounds() {
    // eigenvalues of M = D^{-1/2} A D^{-1/2} lie in [−1, 1]
    // (⇔ normalized-Laplacian eigenvalues in [0, 2])
    forall("normalized affinity spectrum ⊂ [−1, 1]", 15, 408, |g| {
        let n = g.usize_in(8, 60);
        let dim = g.usize_in(1, 4);
        let pts = g.vec_f32(n * dim, -3.0, 3.0);
        let w = vec![1.0f32; n];
        let sigma = g.f64_in(0.3, 3.0);
        let aff = affinity::build(&pts, dim, &w, sigma);
        let mut rng = dsc::rng::Rng::new(g.case as u64);
        let evals = dsc::spectral::njw::top_eigenvalues(&aff, 4.min(n - 1), &mut rng);
        for (j, &e) in evals.iter().enumerate() {
            if !(-1.0 - 1e-6..=1.0 + 1e-6).contains(&e) {
                return Err(format!("λ{j} = {e} out of [−1,1]"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_affinity_symmetric_nonneg_zero_diag() {
    forall("affinity matrix structure", 25, 409, |g| {
        let n = g.usize_in(2, 50);
        let dim = g.usize_in(1, 5);
        let pts = g.vec_f32(n * dim, -4.0, 4.0);
        let w: Vec<f32> = (0..n).map(|_| g.usize_in(1, 100) as f32).collect();
        let sigma = g.f64_in(0.2, 5.0);
        let aff = affinity::build(&pts, dim, &w, sigma);
        for i in 0..n {
            if aff.row(i)[i] != 0.0 {
                return Err(format!("diag[{i}] = {}", aff.row(i)[i]));
            }
            for j in 0..n {
                let a = aff.row(i)[j];
                if a < 0.0 {
                    return Err(format!("negative affinity at ({i},{j})"));
                }
                let b = aff.row(j)[i];
                if (a - b).abs() > 1e-6 * a.abs().max(1.0) {
                    return Err(format!("asymmetry at ({i},{j}): {a} vs {b}"));
                }
            }
        }
        Ok(())
    });
}

// ───────────────────────────── wire codec ─────────────────────────────

#[test]
fn prop_wire_roundtrip() {
    use dsc::net::wire::{decode, encode, Message};
    forall("encode→decode is identity", 60, 510, |g| {
        let msg = match g.usize_in(0, 3) {
            0 => {
                let dim = g.usize_in(1, 8);
                let n = g.usize_in(0, 50);
                Message::Codebook {
                    site: g.usize_in(0, 7) as u32,
                    dim: dim as u32,
                    codewords: g.vec_f32(n * dim, -100.0, 100.0),
                    weights: (0..n).map(|_| g.usize_in(1, 10_000) as u32).collect(),
                }
            }
            1 => {
                let n = g.usize_in(0, 200);
                Message::Labels { site: g.usize_in(0, 7) as u32, labels: g.labels(n, 8) }
            }
            2 => Message::Sigma(g.f64_in(-10.0, 10.0) as f32),
            _ => Message::Ack,
        };
        let back = decode(&encode(&msg)).map_err(|e| e.to_string())?;
        if back == msg {
            Ok(())
        } else {
            Err("roundtrip mismatch".into())
        }
    });
}

mod wire_gen {
    //! Seeded generators for every wire message kind, shared by the
    //! all-tag roundtrip and truncation properties.
    use dsc::dml::DmlKind;
    use dsc::net::wire::{JobReport, JobSpec, LinkReport, Message, RejectCode};
    use dsc::prop::Gen;
    use dsc::spectral::{Algo, Bandwidth, GraphKind};

    fn dml(g: &mut Gen) -> DmlKind {
        [DmlKind::KMeans, DmlKind::RpTree, DmlKind::RandomSample][g.usize_in(0, 2)]
    }

    fn algo(g: &mut Gen) -> Algo {
        [Algo::RecursiveNcut, Algo::Njw][g.usize_in(0, 1)]
    }

    fn graph(g: &mut Gen) -> GraphKind {
        if g.bool(0.5) {
            GraphKind::Dense
        } else {
            GraphKind::Knn { k: g.usize_in(1, 64) }
        }
    }

    fn bandwidth(g: &mut Gen) -> Bandwidth {
        match g.usize_in(0, 2) {
            0 => Bandwidth::Fixed(g.f64_in(0.01, 10.0)),
            1 => Bandwidth::MedianScale(g.f64_in(0.01, 4.0)),
            _ => Bandwidth::EigengapSearch { k: g.usize_in(0, 8) },
        }
    }

    fn spec(g: &mut Gen) -> JobSpec {
        JobSpec {
            dml: dml(g),
            total_codes: g.usize_in(1, 100_000) as u32,
            k_clusters: g.usize_in(1, 64) as u32,
            kmeans_max_iters: g.usize_in(1, 100) as u32,
            kmeans_tol: g.f64_in(1e-9, 1e-2),
            seed: g.rng().next_u64(),
            algo: algo(g),
            graph: graph(g),
            weighted: g.bool(0.5),
            bandwidth: bandwidth(g),
            // the legacy SUBMIT(14) frame has no priority slot, and its
            // encoder asserts the default; tag 18 randomizes it below
            priority: JobSpec::DEFAULT_PRIORITY,
        }
    }

    fn report(g: &mut Gen) -> JobReport {
        let n_sites = g.usize_in(0, 4);
        JobReport {
            n_codes: g.usize_in(0, 100_000) as u32,
            sigma: g.f64_in(0.0, 10.0),
            central_ns: g.rng().next_u64(),
            wall_ns: g.rng().next_u64(),
            per_site: (0..n_sites)
                .map(|_| LinkReport {
                    up_frames: g.usize_in(0, 1000) as u64,
                    up_bytes: g.rng().next_u64(),
                    up_sim_ns: g.rng().next_u64(),
                    down_frames: g.usize_in(0, 1000) as u64,
                    down_bytes: g.rng().next_u64(),
                    down_sim_ns: g.rng().next_u64(),
                })
                .collect(),
        }
    }

    fn text(g: &mut Gen, max: usize) -> String {
        let n = g.usize_in(0, max);
        (0..n).map(|_| (b'a' + g.usize_in(0, 25) as u8) as char).collect()
    }

    fn codebook(g: &mut Gen) -> (u32, Vec<f32>, Vec<u32>) {
        let dim = g.usize_in(1, 6);
        let n = g.usize_in(0, 20);
        (
            dim as u32,
            g.vec_f32(n * dim, -100.0, 100.0),
            (0..n).map(|_| g.usize_in(1, 10_000) as u32).collect(),
        )
    }

    /// A random message carrying exactly wire tag `tag` (1–21).
    pub fn message_with_tag(g: &mut Gen, tag: u8) -> Message {
        let site = g.usize_in(0, 7) as u32;
        let run = g.usize_in(1, 1_000_000) as u32;
        match tag {
            1 => {
                let (dim, codewords, weights) = codebook(g);
                Message::Codebook { site, dim, codewords, weights }
            }
            2 => Message::Labels { site, labels: g.labels(g.usize_in(0, 50), 8) },
            3 => Message::Sigma(g.f64_in(-10.0, 10.0) as f32),
            4 => Message::Ack,
            5 => Message::SiteInfo { site, n_points: g.rng().next_u64() >> 20, dim: 10 },
            6 => Message::DmlRequest {
                site,
                dml: dml(g),
                target_codes: g.usize_in(1, 100_000) as u32,
                max_iters: g.usize_in(1, 100) as u32,
                tol: g.f64_in(1e-9, 1e-2),
                seed: g.rng().next_u64(),
            },
            7 => Message::RunStart { run },
            8 => Message::RunSiteInfo { run, site, n_points: g.rng().next_u64() >> 20, dim: 4 },
            9 => Message::RunDmlRequest {
                run,
                site,
                dml: dml(g),
                target_codes: g.usize_in(1, 100_000) as u32,
                max_iters: g.usize_in(1, 100) as u32,
                tol: g.f64_in(1e-9, 1e-2),
                seed: g.rng().next_u64(),
            },
            10 => {
                let (dim, codewords, weights) = codebook(g);
                Message::RunCodebook { run, site, dim, codewords, weights }
            }
            11 => Message::RunLabels { run, site, labels: g.labels(g.usize_in(0, 50), 8) },
            12 => Message::LabelsPull { run },
            13 => Message::SiteLabels { run, site, labels: g.labels(g.usize_in(0, 50), 8) },
            14 => Message::Submit(spec(g)),
            15 => Message::JobAccept { run },
            16 => Message::JobDone { run, report: report(g) },
            17 => Message::Reject { run, msg: text(g, 60) },
            18 => {
                let mut s = spec(g);
                s.priority = g.usize_in(1, JobSpec::MAX_PRIORITY as usize) as u32;
                Message::SubmitPri(s)
            }
            19 => Message::JobAcceptExt {
                run,
                position: g.usize_in(0, 10_000) as u32,
                eta_ns: g.rng().next_u64(),
            },
            20 => Message::RejectCoded {
                run,
                code: [
                    RejectCode::BadSpec,
                    RejectCode::QueueFull,
                    RejectCode::RateLimited,
                    RejectCode::RunFailed,
                    RejectCode::PullRefused,
                ][g.usize_in(0, 4)],
                detail: g.rng().next_u64(),
                msg: text(g, 60),
            },
            21 => Message::SiteInfo2 {
                site,
                n_points: g.rng().next_u64() >> 20,
                dim: 10,
                digest: g.rng().next_u64(),
                chunks: g.usize_in(0, 1 << 20) as u32,
            },
            other => panic!("no message for tag {other}"),
        }
    }
}

#[test]
fn prop_wire_roundtrip_every_tag() {
    use dsc::net::wire::{decode, encode};
    // tag 0 was never assigned and must always be rejected, like any
    // unknown tag above the table
    assert!(decode(&[0u8]).is_err());
    assert!(decode(&[22u8]).is_err());
    assert!(decode(&[255u8]).is_err());
    forall("encode→decode is identity for every tag 1–21", 25, 513, |g| {
        for tag in 1u8..=21 {
            let msg = wire_gen::message_with_tag(g, tag);
            let frame = encode(&msg);
            if frame[0] != tag {
                return Err(format!("message for tag {tag} encoded as tag {}", frame[0]));
            }
            let back = decode(&frame).map_err(|e| format!("tag {tag}: {e}"))?;
            if back != msg {
                return Err(format!("tag {tag} roundtrip mismatch"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wire_truncation_rejected_at_every_offset() {
    use dsc::net::wire::{decode, encode};
    // Every strict prefix of every frame must decode to an error — no
    // panic, no partial message, and (by the decoder's allocation rule) no
    // reservation beyond the bytes present.
    forall("truncation at every byte offset errors for every tag", 10, 514, |g| {
        for tag in 1u8..=21 {
            let frame = encode(&wire_gen::message_with_tag(g, tag));
            for cut in 0..frame.len() {
                if decode(&frame[..cut]).is_ok() {
                    return Err(format!("tag {tag}: cut at {cut}/{} decoded", frame.len()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wire_hostile_counts_never_overallocate() {
    use dsc::net::wire::decode;
    // Array-carrying frames whose headers declare huge element counts over
    // a near-empty body must fail fast on truncation: the decoder bounds
    // its pre-allocation by the bytes actually remaining in the frame, so
    // a 13-byte hostile frame cannot reserve megabytes before erroring.
    forall("hostile declared counts error without allocating", 40, 515, |g| {
        // 1M–99M declared elements: below the decoder's element cap, so
        // only the truncation/allocation bound can catch it
        let count = (1_000_000u64 + g.rng().next_u64() % 98_000_000) as u32;
        let run = 1u32.to_le_bytes();
        let site = 0u32.to_le_bytes();
        let one = 1u32.to_le_bytes();
        let n = count.to_le_bytes();
        let hostile: Vec<Vec<u8>> = vec![
            // CODEBOOK(1): site dim=1 n=count, empty body
            [&[1u8][..], &site[..], &one[..], &n[..]].concat(),
            // LABELS(2): site n=count
            [&[2u8][..], &site[..], &n[..]].concat(),
            // RCODEBOOK(10): run site dim=1 n=count
            [&[10u8][..], &run[..], &site[..], &one[..], &n[..]].concat(),
            // RLABELS(11): run site n=count
            [&[11u8][..], &run[..], &site[..], &n[..]].concat(),
            // SITELABELS(13): run site n=count
            [&[13u8][..], &run[..], &site[..], &n[..]].concat(),
        ];
        for frame in hostile {
            if decode(&frame).is_ok() {
                return Err(format!("hostile count {count} decoded (tag {})", frame[0]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_decoder_never_panics_on_corruption() {
    use dsc::net::wire::{decode, encode, Message};
    forall("bit-flipped frames error, never panic", 60, 511, |g| {
        let mut frame = encode(&Message::Codebook {
            site: 1,
            dim: 2,
            codewords: g.vec_f32(8, -1.0, 1.0),
            weights: vec![3, 4, 5, 6],
        });
        // flip a few random bytes / truncate
        for _ in 0..g.usize_in(1, 4) {
            let pos = g.usize_in(0, frame.len() - 1);
            frame[pos] ^= 1 << g.usize_in(0, 7);
        }
        if g.bool(0.3) {
            let cut = g.usize_in(0, frame.len());
            frame.truncate(cut);
        }
        let _ = decode(&frame); // must not panic; Err is fine
        Ok(())
    });
}

// ───────────────────────────── DRR fair queue ─────────────────────────────

/// The deficit round-robin guarantee, under ANY interleaving of the
/// clients' submit sequences: while every client stays backlogged, no
/// client's weight-normalized service count (`served / weight`) runs more
/// than one full round ahead of another's. Also pins conservation (every
/// pushed item pops exactly once) and strict per-client FIFO order.
#[test]
fn prop_drr_backlogged_service_tracks_weights() {
    use dsc::coordinator::server::DrrQueue;

    forall("DRR service shares track weights while backlogged", 60, 717, |g| {
        let k = g.usize_in(2, 5);
        let weights: Vec<u32> = (0..k).map(|_| g.usize_in(1, 4) as u32).collect();
        let counts: Vec<usize> = (0..k).map(|_| g.usize_in(1, 12)).collect();

        // an arbitrary interleaving of the per-client submit sequences
        let mut order: Vec<usize> =
            (0..k).flat_map(|c| std::iter::repeat(c).take(counts[c])).collect();
        for i in (1..order.len()).rev() {
            order.swap(i, g.usize_in(0, i));
        }

        let mut q = DrrQueue::new();
        let mut seq = vec![0u32; k];
        for &c in &order {
            q.push(c as u64, weights[c], (c, seq[c]));
            seq[c] += 1;
        }
        let total: usize = counts.iter().sum();
        if q.len() != total {
            return Err(format!("len {} after {total} pushes", q.len()));
        }

        let mut served = vec![0usize; k];
        let mut next_seq = vec![0u32; k];
        let mut popped = 0usize;
        let mut backlogged = true;
        while let Some((c, s)) = q.pop() {
            popped += 1;
            if s != next_seq[c] {
                return Err(format!(
                    "client {c}: item {s} out of FIFO order (expected {})",
                    next_seq[c]
                ));
            }
            next_seq[c] += 1;
            served[c] += 1;
            if served[c] == counts[c] {
                // first lane drained: the fully-backlogged window is over
                backlogged = false;
            }
            if backlogged {
                let shares: Vec<f64> =
                    (0..k).map(|i| served[i] as f64 / weights[i] as f64).collect();
                let max = shares.iter().cloned().fold(f64::MIN, f64::max);
                let min = shares.iter().cloned().fold(f64::MAX, f64::min);
                if max - min > 1.0 + 1e-9 {
                    return Err(format!(
                        "after {popped} pops served={served:?} weights={weights:?}: \
                         share spread {}",
                        max - min
                    ));
                }
            }
        }
        if popped != total {
            return Err(format!("popped {popped} of {total}"));
        }
        if !q.is_empty() {
            return Err("queue non-empty after full drain".into());
        }
        Ok(())
    });
}

/// For a single client DRR degrades to plain FIFO — so `fair_queue =
/// true` with one tenant schedules exactly like the legacy queue.
#[test]
fn prop_drr_single_client_is_fifo() {
    use dsc::coordinator::server::DrrQueue;

    forall("single-client DRR pops in push order", 40, 718, |g| {
        let n = g.usize_in(0, 30);
        let mut q = DrrQueue::new();
        for i in 0..n {
            // per-job weights may vary; order must not
            q.push(9, g.usize_in(1, 16) as u32, i);
        }
        for want in 0..n {
            match q.pop() {
                Some(got) if got == want => {}
                other => return Err(format!("pop {want} returned {other:?}")),
            }
        }
        if q.pop().is_some() {
            return Err("pop after drain returned an item".into());
        }
        Ok(())
    });
}

/// The canonical skewed 3-tenant mix's DRR pop order, pinned by hand:
/// the weight-4 tenant drains inside the first ring round while the
/// weight-1 heavy tenant queues behind it — the exact schedule the
/// recorded BENCH trajectory's fairness numbers are computed from
/// (`coordinator::loadgen`, `benches/jobserver_load.rs`).
#[test]
fn drr_pop_order_on_the_skewed_mix_is_pinned() {
    use dsc::coordinator::server::DrrQueue;

    let budgets: [(u64, u32, usize, &str); 3] =
        [(1, 1, 12, "A"), (2, 2, 6, "B"), (3, 4, 3, "C")];
    let mut q = DrrQueue::new();
    let mut next = [0usize; 3];
    // round-robin arrivals while budgets last: the load generator's
    // submit order (A1 B1 C1 A2 B2 C2 … A12)
    loop {
        let mut any = false;
        for (i, &(client, w, n, name)) in budgets.iter().enumerate() {
            if next[i] < n {
                q.push(client, w, format!("{name}{}", next[i] + 1));
                next[i] += 1;
                any = true;
            }
        }
        if !any {
            break;
        }
    }
    let mut order = Vec::new();
    while let Some(item) = q.pop() {
        order.push(item);
    }
    let expected = [
        "A1", "B1", "B2", "C1", "C2", "C3", "A2", "B3", "B4", "A3", "B5", "B6", "A4", "A5",
        "A6", "A7", "A8", "A9", "A10", "A11", "A12",
    ];
    assert_eq!(order, expected);
}

/// PR-5 parity pin: the legacy client-facing reply frames are
/// byte-frozen. A legacy (tag-14) submitter must keep receiving these
/// exact bytes from a `fair_queue = false` leader — the modern
/// JOBACCEPT2(19)/REJECT2(20) replies go only to tag-18 submitters.
#[test]
fn legacy_job_reply_frames_are_byte_frozen() {
    use dsc::net::wire::{encode, Message};

    // JOBACCEPT(15) := run:u32 — little-endian, no position/ETA suffix
    assert_eq!(encode(&Message::JobAccept { run: 7 }), vec![15, 7, 0, 0, 0]);
    // REJECT(17) := run:u32 len:u32 msg — free text, no code/detail
    assert_eq!(
        encode(&Message::Reject { run: 3, msg: "no".into() }),
        vec![17, 3, 0, 0, 0, 2, 0, 0, 0, b'n', b'o']
    );
}

// ───────────────────────────── straggler deadlines ─────────────────────────────

/// A run's straggler deadline fires exactly once under arbitrary `Tick`
/// jitter: ticks strictly before the (phase-current) deadline are always
/// harmless, the first tick at or past it errors with the canonical
/// straggler text, and — since the driver contract discards an errored
/// machine — nothing fires twice. Registrations interleave at random
/// times, including the full set (which moves the deadline to the
/// codebook phase); the model tracks the expected deadline independently.
#[test]
fn prop_deadline_fires_exactly_once_under_tick_jitter() {
    use dsc::coordinator::machine::{RunInput, RunMachine};
    use dsc::dml::DmlKind;
    use dsc::net::JobSpec;
    use dsc::spectral::{Algo, Bandwidth, GraphKind};
    use std::time::{Duration, Instant};

    fn spec(seed: u64) -> JobSpec {
        JobSpec {
            dml: DmlKind::KMeans,
            total_codes: 64,
            k_clusters: 2,
            kmeans_max_iters: 30,
            kmeans_tol: 1e-6,
            seed,
            algo: Algo::RecursiveNcut,
            graph: GraphKind::Dense,
            weighted: false,
            bandwidth: Bandwidth::MedianScale(0.5),
            priority: JobSpec::DEFAULT_PRIORITY,
        }
    }

    enum Ev {
        Tick,
        Register(usize),
    }

    forall("deadline fires exactly once under tick jitter", 60, 616, |g| {
        let n_sites = g.usize_in(1, 3);
        let timeout_ms = g.usize_in(50, 300) as u64;
        let t0 = Instant::now();
        let mut m =
            RunMachine::new(n_sites, spec(7), Duration::from_millis(timeout_ms), t0);

        // random ticks + a random subset of registrations, in time order
        let mut events: Vec<(u64, Ev)> = Vec::new();
        for _ in 0..g.usize_in(1, 12) {
            events.push((g.usize_in(0, 700) as u64, Ev::Tick));
        }
        let k_reg = g.usize_in(0, n_sites);
        for &site in g.permutation(n_sites).iter().take(k_reg) {
            events.push((g.usize_in(0, 700) as u64, Ev::Register(site)));
        }
        // stable sort: same-instant events keep insertion order, and the
        // model below walks them in exactly the machine's order
        events.sort_by_key(|&(t, _)| t);

        let mut deadline_ms = timeout_ms;
        let mut registered = 0usize;
        let mut fired = false;
        for (t_ms, ev) in events {
            let now = t0 + Duration::from_millis(t_ms);
            match ev {
                Ev::Register(site) => {
                    // registrations are never deadline-checked; the one
                    // completing the set resets the clock for codebooks
                    m.advance(
                        now,
                        RunInput::SiteInfo {
                            site,
                            n_points: 100 * (site as u64 + 1),
                            dim: 3,
                        },
                    )
                    .map_err(|e| format!("registration at {t_ms}ms errored: {e}"))?;
                    registered += 1;
                    if registered == n_sites {
                        deadline_ms = t_ms + timeout_ms;
                    }
                }
                Ev::Tick => {
                    let res = m.advance(now, RunInput::Tick);
                    let should_fire = t_ms >= deadline_ms;
                    match (res, should_fire) {
                        (Ok(_), false) => {}
                        (Err(e), true) => {
                            let msg = e.to_string();
                            if !msg.contains("collect failed") {
                                return Err(format!("wrong straggler error: {msg}"));
                            }
                            fired = true;
                            // the driver discards the machine here; no
                            // second firing is possible by construction
                            break;
                        }
                        (Ok(_), true) => {
                            return Err(format!(
                                "tick at {t_ms}ms ≥ deadline {deadline_ms}ms did not fire"
                            ));
                        }
                        (Err(e), false) => {
                            return Err(format!(
                                "tick at {t_ms}ms < deadline {deadline_ms}ms fired: {e}"
                            ));
                        }
                    }
                }
            }
        }
        let _ = fired; // 0 or 1 firings, checked tick by tick above
        Ok(())
    });
}

// ───────────────────────────── end-to-end invariant ─────────────────────────────

#[test]
fn prop_pipeline_label_count_and_range() {
    use dsc::config::PipelineConfig;
    use dsc::coordinator::run_pipeline;
    forall("pipeline emits one label per point, in range", 8, 612, |g| {
        let comps = vec![
            gmm::Component::isotropic(vec![0.0, 0.0], 0.4, 1.0),
            gmm::Component::isotropic(vec![8.0, 8.0], 0.4, 1.0),
        ];
        let ds = gmm::sample("p", &comps, g.usize_in(200, 1200), g.rng().next_u64());
        let n_sites = g.usize_in(1, 3).max(2);
        let parts = scenario::split(&ds, Scenario::D3, n_sites, g.rng().next_u64());
        let cfg = PipelineConfig {
            total_codes: g.usize_in(8, 64),
            k_clusters: 2,
            seed: g.rng().next_u64(),
            ..Default::default()
        };
        let report = run_pipeline(&parts, &cfg).map_err(|e| e.to_string())?;
        if report.labels.len() != ds.len() {
            return Err(format!("{} labels for {} points", report.labels.len(), ds.len()));
        }
        if report.labels.iter().any(|&l| l as usize >= cfg.k_clusters) {
            return Err("label out of range".into());
        }
        Ok(())
    });
}
