//! Property-based invariants across the whole stack (DESIGN.md §6),
//! using the in-crate `prop` harness.

use dsc::data::scenario::{self, Scenario};
use dsc::data::{gmm, Dataset};
use dsc::dml::{self, DmlKind, DmlParams};
use dsc::metrics::{adjusted_rand_index, clustering_accuracy, hungarian_max};
use dsc::prop::{forall, Gen};
use dsc::spectral::affinity;

fn random_dataset(g: &mut Gen, max_n: usize) -> Dataset {
    let n_classes = g.usize_in(1, 4);
    let dim = g.usize_in(1, 6);
    let n = g.usize_in(n_classes, max_n);
    let mut ds = Dataset::new("prop", dim, n_classes);
    for _ in 0..n {
        let label = g.usize_in(0, n_classes - 1) as u16;
        let coords = g.vec_f32(dim, -5.0, 5.0);
        ds.push(&coords, label);
    }
    ds
}

// ───────────────────────────── scenario splits ─────────────────────────────

#[test]
fn prop_splits_partition_the_data() {
    forall("splits conserve and never duplicate points", 40, 101, |g| {
        let ds = random_dataset(g, 400);
        let n_sites = g.usize_in(2, 4);
        let sc = [Scenario::D1, Scenario::D2, Scenario::D3][g.usize_in(0, 2)];
        let seed = g.rng().next_u64();
        let parts = scenario::split(&ds, sc, n_sites, seed);

        let total: usize = parts.iter().map(|p| p.data.len()).sum();
        if total != ds.len() {
            return Err(format!("{sc} lost points: {total} vs {}", ds.len()));
        }
        let mut seen = vec![false; ds.len()];
        for p in &parts {
            for (local, &g_idx) in p.global_idx.iter().enumerate() {
                if seen[g_idx as usize] {
                    return Err(format!("point {g_idx} duplicated"));
                }
                seen[g_idx as usize] = true;
                if p.data.point(local) != ds.point(g_idx as usize) {
                    return Err(format!("coords corrupted for {g_idx}"));
                }
                if p.data.labels[local] != ds.labels[g_idx as usize] {
                    return Err(format!("label corrupted for {g_idx}"));
                }
            }
        }
        if !seen.iter().all(|&b| b) {
            return Err("some points unassigned".into());
        }
        Ok(())
    });
}

// ───────────────────────────── codebooks ─────────────────────────────

#[test]
fn prop_codebooks_are_consistent() {
    forall("codebook weights sum to site size; assignments in range", 25, 202, |g| {
        let ds = random_dataset(g, 600);
        let kind = if g.bool(0.5) { DmlKind::KMeans } else { DmlKind::RpTree };
        let target = g.usize_in(1, 40);
        let params = DmlParams {
            kind,
            target_codes: target,
            max_iters: 10,
            tol: 1e-6,
            seed: g.rng().next_u64(),
        };
        let cb = dml::apply(&ds, &params);
        cb.validate(ds.len()).map_err(|e| format!("{kind}: {e}"))
    });
}

#[test]
fn prop_distortion_bounded_by_data_radius() {
    forall("quantization distortion ≤ max squared pairwise distance", 20, 203, |g| {
        let ds = random_dataset(g, 300);
        if ds.is_empty() {
            return Ok(());
        }
        let params = DmlParams {
            kind: DmlKind::KMeans,
            target_codes: g.usize_in(1, 20),
            max_iters: 8,
            tol: 1e-6,
            seed: 1,
        };
        let cb = dml::apply(&ds, &params);
        // coords live in [-5, 5]^dim ⇒ ‖x − q(x)‖² ≤ dim · 10²
        let bound = (ds.dim as f64) * 100.0;
        let d = cb.distortion(&ds);
        if d <= bound {
            Ok(())
        } else {
            Err(format!("distortion {d} exceeds bound {bound}"))
        }
    });
}

// ───────────────────────────── metrics ─────────────────────────────

#[test]
fn prop_accuracy_is_permutation_invariant() {
    forall("relabelling predictions never changes accuracy", 60, 304, |g| {
        let k = g.usize_in(1, 6);
        let n = g.usize_in(1, 200);
        let truth = g.labels(n, k);
        let pred = g.labels(n, k);
        let perm = g.permutation(k);
        let permuted: Vec<u16> = pred.iter().map(|&l| perm[l as usize] as u16).collect();
        let a = clustering_accuracy(&truth, &pred);
        let b = clustering_accuracy(&truth, &permuted);
        if (a - b).abs() < 1e-12 {
            Ok(())
        } else {
            Err(format!("{a} vs {b}"))
        }
    });
}

#[test]
fn prop_accuracy_bounds_and_perfection() {
    forall("accuracy ∈ [0, 1]; exact on identical labelings", 60, 305, |g| {
        let k = g.usize_in(1, 6);
        let n = g.usize_in(1, 200);
        let truth = g.labels(n, k);
        let acc_self = clustering_accuracy(&truth, &truth);
        if acc_self != 1.0 {
            return Err(format!("self-accuracy {acc_self}"));
        }
        let pred = g.labels(n, k);
        let acc = clustering_accuracy(&truth, &pred);
        if !(0.0..=1.0).contains(&acc) {
            return Err(format!("accuracy out of range: {acc}"));
        }
        Ok(())
    });
}

#[test]
fn prop_hungarian_at_least_greedy() {
    forall("hungarian ≥ greedy row assignment", 60, 306, |g| {
        let rows = g.usize_in(1, 7);
        let cols = g.usize_in(1, 7);
        let profit: Vec<Vec<f64>> = (0..rows)
            .map(|_| (0..cols).map(|_| g.f64_in(0.0, 100.0)).collect())
            .collect();
        let (best, _) = hungarian_max(&profit);
        // greedy: rows in order take their max still-free column
        let mut used = vec![false; cols];
        let mut greedy = 0.0;
        for row in &profit {
            let mut pick: Option<(usize, f64)> = None;
            for (c, &v) in row.iter().enumerate() {
                if !used[c] && pick.map_or(true, |(_, pv)| v > pv) {
                    pick = Some((c, v));
                }
            }
            if let Some((c, v)) = pick {
                used[c] = true;
                greedy += v;
            }
        }
        if best + 1e-9 >= greedy {
            Ok(())
        } else {
            Err(format!("hungarian {best} < greedy {greedy}"))
        }
    });
}

#[test]
fn prop_ari_agrees_on_perfect_match() {
    forall("ARI = 1 on labelings identical up to permutation", 40, 307, |g| {
        let k = g.usize_in(2, 5);
        let n = g.usize_in(k * 2, 150);
        let truth = g.labels(n, k);
        let perm = g.permutation(k);
        let relabeled: Vec<u16> = truth.iter().map(|&l| perm[l as usize] as u16).collect();
        let ari = adjusted_rand_index(&truth, &relabeled);
        if (ari - 1.0).abs() < 1e-9 {
            Ok(())
        } else {
            Err(format!("ARI {ari}"))
        }
    });
}

// ───────────────────────────── spectral invariants ─────────────────────────────

#[test]
fn prop_laplacian_spectrum_in_bounds() {
    // eigenvalues of M = D^{-1/2} A D^{-1/2} lie in [−1, 1]
    // (⇔ normalized-Laplacian eigenvalues in [0, 2])
    forall("normalized affinity spectrum ⊂ [−1, 1]", 15, 408, |g| {
        let n = g.usize_in(8, 60);
        let dim = g.usize_in(1, 4);
        let pts = g.vec_f32(n * dim, -3.0, 3.0);
        let w = vec![1.0f32; n];
        let sigma = g.f64_in(0.3, 3.0);
        let aff = affinity::build(&pts, dim, &w, sigma);
        let mut rng = dsc::rng::Rng::new(g.case as u64);
        let evals = dsc::spectral::njw::top_eigenvalues(&aff, 4.min(n - 1), &mut rng);
        for (j, &e) in evals.iter().enumerate() {
            if !(-1.0 - 1e-6..=1.0 + 1e-6).contains(&e) {
                return Err(format!("λ{j} = {e} out of [−1,1]"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_affinity_symmetric_nonneg_zero_diag() {
    forall("affinity matrix structure", 25, 409, |g| {
        let n = g.usize_in(2, 50);
        let dim = g.usize_in(1, 5);
        let pts = g.vec_f32(n * dim, -4.0, 4.0);
        let w: Vec<f32> = (0..n).map(|_| g.usize_in(1, 100) as f32).collect();
        let sigma = g.f64_in(0.2, 5.0);
        let aff = affinity::build(&pts, dim, &w, sigma);
        for i in 0..n {
            if aff.row(i)[i] != 0.0 {
                return Err(format!("diag[{i}] = {}", aff.row(i)[i]));
            }
            for j in 0..n {
                let a = aff.row(i)[j];
                if a < 0.0 {
                    return Err(format!("negative affinity at ({i},{j})"));
                }
                let b = aff.row(j)[i];
                if (a - b).abs() > 1e-6 * a.abs().max(1.0) {
                    return Err(format!("asymmetry at ({i},{j}): {a} vs {b}"));
                }
            }
        }
        Ok(())
    });
}

// ───────────────────────────── wire codec ─────────────────────────────

#[test]
fn prop_wire_roundtrip() {
    use dsc::net::wire::{decode, encode, Message};
    forall("encode→decode is identity", 60, 510, |g| {
        let msg = match g.usize_in(0, 3) {
            0 => {
                let dim = g.usize_in(1, 8);
                let n = g.usize_in(0, 50);
                Message::Codebook {
                    site: g.usize_in(0, 7) as u32,
                    dim: dim as u32,
                    codewords: g.vec_f32(n * dim, -100.0, 100.0),
                    weights: (0..n).map(|_| g.usize_in(1, 10_000) as u32).collect(),
                }
            }
            1 => {
                let n = g.usize_in(0, 200);
                Message::Labels { site: g.usize_in(0, 7) as u32, labels: g.labels(n, 8) }
            }
            2 => Message::Sigma(g.f64_in(-10.0, 10.0) as f32),
            _ => Message::Ack,
        };
        let back = decode(&encode(&msg)).map_err(|e| e.to_string())?;
        if back == msg {
            Ok(())
        } else {
            Err("roundtrip mismatch".into())
        }
    });
}

#[test]
fn prop_decoder_never_panics_on_corruption() {
    use dsc::net::wire::{decode, encode, Message};
    forall("bit-flipped frames error, never panic", 60, 511, |g| {
        let mut frame = encode(&Message::Codebook {
            site: 1,
            dim: 2,
            codewords: g.vec_f32(8, -1.0, 1.0),
            weights: vec![3, 4, 5, 6],
        });
        // flip a few random bytes / truncate
        for _ in 0..g.usize_in(1, 4) {
            let pos = g.usize_in(0, frame.len() - 1);
            frame[pos] ^= 1 << g.usize_in(0, 7);
        }
        if g.bool(0.3) {
            let cut = g.usize_in(0, frame.len());
            frame.truncate(cut);
        }
        let _ = decode(&frame); // must not panic; Err is fine
        Ok(())
    });
}

// ───────────────────────────── end-to-end invariant ─────────────────────────────

#[test]
fn prop_pipeline_label_count_and_range() {
    use dsc::config::PipelineConfig;
    use dsc::coordinator::run_pipeline;
    forall("pipeline emits one label per point, in range", 8, 612, |g| {
        let comps = vec![
            gmm::Component::isotropic(vec![0.0, 0.0], 0.4, 1.0),
            gmm::Component::isotropic(vec![8.0, 8.0], 0.4, 1.0),
        ];
        let ds = gmm::sample("p", &comps, g.usize_in(200, 1200), g.rng().next_u64());
        let n_sites = g.usize_in(1, 3).max(2);
        let parts = scenario::split(&ds, Scenario::D3, n_sites, g.rng().next_u64());
        let cfg = PipelineConfig {
            total_codes: g.usize_in(8, 64),
            k_clusters: 2,
            seed: g.rng().next_u64(),
            ..Default::default()
        };
        let report = run_pipeline(&parts, &cfg).map_err(|e| e.to_string())?;
        if report.labels.len() != ds.len() {
            return Err(format!("{} labels for {} points", report.labels.len(), ds.len()));
        }
        if report.labels.iter().any(|&l| l as usize >= cfg.k_clusters) {
            return Err("label out of range".into());
        }
        Ok(())
    });
}
