//! Sparse-vs-dense spectral parity (ISSUE 2 acceptance).
//!
//! At `k = m − 1` the sparse k-NN builder keeps every neighbor, and its
//! expanded-form f32 weight arithmetic matches the dense builder bit for
//! bit — so the two graphs are the *same* operator in different storage.
//! These tests pin that equivalence end to end: entries, degrees,
//! eigenvalues, eigenvectors, and final cluster assignments on a small
//! well-separated GMM (the quickstart mixture family).

use dsc::data::gmm;
use dsc::metrics::clustering_accuracy;
use dsc::rng::Rng;
use dsc::spectral::{
    affinity, njw, sparse, Algo, Bandwidth, GraphKind, SpectralParams,
};

/// Small well-separated 4-component GMM (same family as the pipeline
/// quickstart, scaled down so the dense path is cheap to compare against).
fn gmm4(n: usize, seed: u64) -> dsc::data::Dataset {
    let comps = vec![
        gmm::Component::isotropic(vec![0.0, 0.0], 0.5, 1.0),
        gmm::Component::isotropic(vec![12.0, 0.0], 0.5, 1.0),
        gmm::Component::isotropic(vec![0.0, 12.0], 0.5, 1.0),
        gmm::Component::isotropic(vec![12.0, 12.0], 0.5, 1.0),
    ];
    gmm::sample("gmm4", &comps, n, seed)
}

/// Two-component variant with *moderate* separation: the blobs couple
/// enough that λ₂ is simple and well-gapped from both λ₁ and λ₃, so the
/// second eigenvector is well-conditioned and comparable across storages
/// (fully separated blobs would make λ₁ ≈ λ₂ degenerate and the individual
/// vectors arbitrary up to rotation).
fn gmm2(n: usize, seed: u64) -> dsc::data::Dataset {
    let comps = vec![
        gmm::Component::isotropic(vec![0.0, 0.0], 0.5, 1.0),
        gmm::Component::isotropic(vec![4.0, 0.0], 0.5, 1.0),
    ];
    gmm::sample("gmm2", &comps, n, seed)
}

#[test]
fn full_k_graphs_are_the_same_operator() {
    let ds = gmm4(120, 3);
    let m = ds.len();
    let w = vec![1.0f32; m];
    let dense = affinity::build(&ds.points, 2, &w, 1.5);
    let mut rng = Rng::new(5);
    let sp = sparse::build_knn(&ds.points, 2, &w, 1.5, m - 1, &mut rng);

    assert_eq!(sp.nnz(), m * (m - 1), "full-k graph must be complete");
    for i in 0..m {
        let (cols, vals) = sp.row(i);
        for (c, v) in cols.iter().zip(vals) {
            assert_eq!(v.to_bits(), dense.row(i)[*c as usize].to_bits());
        }
        assert_eq!(sp.deg[i].to_bits(), dense.deg[i].to_bits());
    }
}

#[test]
fn eigenvalues_and_second_eigenvector_agree() {
    let ds = gmm2(100, 7);
    let m = ds.len();
    let w = vec![1.0f32; m];
    let dense = affinity::build(&ds.points, 2, &w, 1.5);
    let mut grng = Rng::new(9);
    let sp = sparse::build_knn(&ds.points, 2, &w, 1.5, m - 1, &mut grng);

    let mut r1 = Rng::new(11);
    let mut r2 = Rng::new(11);
    let ed = njw::top_eigenvalues(&dense, 3, &mut r1);
    let es = njw::top_eigenvalues(&sp, 3, &mut r2);
    for (a, b) in ed.iter().zip(&es) {
        assert!((a - b).abs() < 1e-9, "eigenvalue {a} vs {b}");
    }

    // v2 is simple for two blobs → compare the embedding column up to sign
    let mut r1 = Rng::new(13);
    let mut r2 = Rng::new(13);
    let embd = njw::embed(&dense, 2, &mut r1);
    let embs = njw::embed(&sp, 2, &mut r2);
    let dot: f64 = (0..m).map(|i| embd[i * 2 + 1] * embs[i * 2 + 1]).sum();
    let sign = if dot >= 0.0 { 1.0 } else { -1.0 };
    for i in 0..m {
        let (a, b) = (embd[i * 2 + 1], sign * embs[i * 2 + 1]);
        assert!((a - b).abs() < 1e-6, "v2[{i}]: {a} vs {b}");
    }
}

#[test]
fn labels_identical_up_to_permutation_both_algorithms() {
    let ds = gmm4(160, 17);
    let m = ds.len();
    for algo in [Algo::RecursiveNcut, Algo::Njw] {
        let base = SpectralParams {
            k: 4,
            algo,
            seed: 19,
            bandwidth: Bandwidth::Fixed(1.5),
            ..Default::default()
        };
        let sparse_params =
            SpectralParams { graph: GraphKind::Knn { k: m - 1 }, ..base.clone() };
        let (ld, id) = dsc::spectral::cluster_codewords(&ds.points, 2, None, &base);
        let (ls, is) = dsc::spectral::cluster_codewords(&ds.points, 2, None, &sparse_params);
        // agreement of the two labelings up to label permutation
        assert_eq!(
            clustering_accuracy(&ld, &ls),
            1.0,
            "{algo:?}: sparse and dense labels disagree"
        );
        // both must also actually solve the problem
        let acc = clustering_accuracy(&ds.labels, &ld);
        assert!(acc > 0.99, "{algo:?}: dense accuracy {acc}");
        for (a, b) in id.top_evals.iter().zip(&is.top_evals) {
            assert!((a - b).abs() < 1e-8, "{algo:?}: eigenvalue {a} vs {b}");
        }
    }
}

#[test]
fn truncated_knn_still_solves_the_gmm() {
    // the approximate regime (k ≪ m): same clusters, a fraction of the edges
    let ds = gmm4(160, 23);
    let params = SpectralParams {
        k: 4,
        algo: Algo::RecursiveNcut,
        seed: 29,
        bandwidth: Bandwidth::MedianScale(0.3),
        graph: GraphKind::Knn { k: 12 },
        ..Default::default()
    };
    let (labels, _) = dsc::spectral::cluster_codewords(&ds.points, 2, None, &params);
    assert_eq!(clustering_accuracy(&ds.labels, &labels), 1.0);
}
