//! TCP parity/smoke layer over the job server. The core multi-run cases —
//! concurrency parity, central-offload pipelining, straggler deadlines,
//! fault behavior, submit/pull policy — live socket-free in
//! `rust/tests/channel_harness.rs`; this file keeps only what genuinely
//! needs sockets: (1) that the TCP job server produces labels and per-run
//! byte counters identical to the channel harness and the in-process
//! pipeline for concurrent jobs over real loopback connections, and
//! (2) the re-dial path — a mid-run site death failing only the affected
//! run while the queue drains onto a re-dialed link, which channel links
//! (unrevivable by design) cannot express.
//! (`examples/tcp_cluster.rs` re-proves the headline flow with separate
//! OS processes.)

mod common;

use std::time::Duration;

use common::pull_global;
use dsc::config::PipelineConfig;
use dsc::coordinator::harness::{serve_channel, HarnessOpts};
use dsc::coordinator::server::{serve_jobs, JobClient, ServerOpts, ServerStats};
use dsc::coordinator::{run_pipeline, spec_from_config};
use dsc::data::gmm;
use dsc::data::scenario::{self, Scenario, SitePart};
use dsc::net::tcp::{SiteListener, TcpTimeouts};
use dsc::net::{JobReport, JobSpec, Message, SiteNet};
use dsc::site::SessionLimits;
use dsc::spectral::Bandwidth;

fn timeouts() -> TcpTimeouts {
    TcpTimeouts {
        connect: Duration::from_secs(5),
        io: Duration::from_secs(10),
        max_idle: Duration::ZERO,
    }
}

fn workload() -> (dsc::data::Dataset, Vec<SitePart>) {
    let ds = gmm::paper_mixture_10d(2_000, 0.1, 21);
    let parts = scenario::split(&ds, Scenario::D3, 2, 21);
    (ds, parts)
}

fn cfg_with_seed(seed: u64) -> PipelineConfig {
    PipelineConfig {
        total_codes: 64,
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed,
        ..Default::default()
    }
}

/// One job's result as a client saw it: the leader's report plus the
/// pulled per-point labels assembled into the global vector
/// (`common::pull_global`).
struct ServedJob {
    report: JobReport,
    labels: Vec<u16>,
}

/// Stand up persistent site sessions + a TCP job server, push `specs`
/// through it concurrently (all submitted before any result is awaited),
/// pull every run's labels, and tear everything down cleanly.
fn serve_and_submit_tcp(parts: &[SitePart], specs: &[JobSpec]) -> (Vec<ServedJob>, ServerStats) {
    let mut addrs = Vec::new();
    let mut site_threads = Vec::new();
    for part in parts {
        let listener = SiteListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        let data = part.data.clone();
        site_threads.push(std::thread::spawn(move || {
            let conn = listener.accept(&timeouts()).unwrap();
            assert!(conn.session_mode(), "a job server must open sessions");
            let net = SiteNet::over(Box::new(conn));
            // one persistent session serves every run of this test
            dsc::site::session(&net, &data, None, SessionLimits::default(), |_| {}).unwrap()
        }));
    }

    let mut cfg = cfg_with_seed(0);
    cfg.net.sites = addrs;
    let opts = ServerOpts {
        max_jobs: specs.len().max(1),
        queue_depth: 8,
        allow_label_pull: true,
        client_limit: Some(specs.len() as u64),
        ..Default::default()
    };
    let client_listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let leader_addr = client_listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn({
        let cfg = cfg.clone();
        let opts = opts.clone();
        move || serve_jobs(&cfg, &opts, client_listener).unwrap()
    });

    // every job in flight before any result is awaited
    let clients: Vec<JobClient> =
        specs.iter().map(|_| JobClient::connect(&leader_addr, &timeouts()).unwrap()).collect();
    let runs: Vec<u32> =
        clients.iter().zip(specs).map(|(c, s)| c.submit(s).unwrap()).collect();
    let mut served = Vec::new();
    for (client, run) in clients.iter().zip(&runs) {
        let report = client.await_done(*run).unwrap();
        let labels = pull_global(client, *run, &report, parts);
        served.push(ServedJob { report, labels });
    }
    drop(clients); // disconnect: lets the server reach its client_limit

    let stats = server.join().unwrap();
    // the server dropping its site links ends every session cleanly
    for t in site_threads {
        let outcome = t.join().unwrap();
        assert_eq!(outcome.aborted_runs, 0);
    }
    (served, stats)
}

/// The same jobs through the socket-free channel harness, for the
/// cross-backend parity check.
fn serve_and_submit_channel(parts: &[SitePart], specs: &[JobSpec]) -> Vec<ServedJob> {
    let cfg = cfg_with_seed(0);
    let opts = HarnessOpts {
        server: ServerOpts {
            max_jobs: specs.len().max(1),
            queue_depth: 8,
            allow_label_pull: true,
            client_limit: Some(specs.len() as u64),
            ..Default::default()
        },
        ..Default::default()
    };
    let datasets = parts.iter().map(|p| p.data.clone()).collect();
    let mut harness = serve_channel(datasets, &cfg, opts).unwrap();
    let clients: Vec<_> = specs.iter().map(|_| harness.client()).collect();
    let runs: Vec<u32> =
        clients.iter().zip(specs).map(|(c, s)| c.submit(s).unwrap()).collect();
    let mut served = Vec::new();
    for (client, run) in clients.iter().zip(&runs) {
        let report = client.await_done(*run).unwrap();
        let labels = pull_global(client, *run, &report, parts);
        served.push(ServedJob { report, labels });
    }
    drop(clients);
    harness.join().unwrap();
    served
}

/// The acceptance headline over real loopback sockets: two jobs submitted
/// concurrently to one TCP leader complete with labels and per-run,
/// per-link byte counters identical to the channel job server running the
/// same jobs — and identical labels to the in-process channel pipeline,
/// with each site's shard served from one session (loaded exactly once).
/// The byte counters are kept above the transport seam, so TCP ≡ channel
/// is by construction; this pins it.
#[test]
fn concurrent_tcp_jobs_match_channel_server_and_pipeline() {
    let (_ds, parts) = workload();
    let spec_a = spec_from_config(&cfg_with_seed(21));
    let spec_b = spec_from_config(&cfg_with_seed(77));
    let specs = [spec_a, spec_b];

    let base_a = run_pipeline(&parts, &cfg_with_seed(21)).unwrap();
    let base_b = run_pipeline(&parts, &cfg_with_seed(77)).unwrap();

    let (tcp, stats) = serve_and_submit_tcp(&parts, &specs);
    let channel = serve_and_submit_channel(&parts, &specs);
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.failed, 0);

    for (i, base) in [&base_a, &base_b].into_iter().enumerate() {
        // labels: TCP == channel job server == the channel pipeline
        assert_eq!(tcp[i].labels, base.labels, "job {i} vs pipeline");
        assert_eq!(tcp[i].labels, channel[i].labels, "job {i} vs channel server");

        // per-run, per-link counters: byte-for-byte across transports
        let (t, c) = (&tcp[i].report, &channel[i].report);
        assert_eq!(t.n_codes, c.n_codes, "job {i} codes");
        assert_eq!(t.sigma, c.sigma, "job {i} sigma");
        assert_eq!(t.per_site, c.per_site, "job {i} per-link counters");

        // the run-scoped dialect is exactly 2 frames up (registration +
        // codebook) and 3 down (run open + work order + labels) per site
        for (sid, l) in t.per_site.iter().enumerate() {
            assert_eq!(l.up_frames, 2, "job {i} site {sid} up frames");
            assert_eq!(l.down_frames, 3, "job {i} site {sid} down frames");
        }
    }
    // two different seeds really are two different clusterings of the
    // same data (guards against comparing a job with itself)
    assert_ne!(tcp[0].labels, tcp[1].labels);
}

/// A site dying mid-run fails only the run that was in flight: the queued
/// job behind it is served after the leader re-dials the restarted site,
/// over the surviving site's original session. Re-dial is a TCP-only
/// behavior (channel links cannot be revived), so this is the one failure
/// case that stays socket-bound.
#[test]
fn site_death_fails_one_run_and_the_queue_drains() {
    let (_ds, parts) = workload();
    let spec = spec_from_config(&cfg_with_seed(21));
    let base = run_pipeline(&parts, &cfg_with_seed(21)).unwrap();

    // site 0: one healthy persistent session for the whole test
    let l0 = SiteListener::bind("127.0.0.1:0").unwrap();
    let addr0 = l0.local_addr().unwrap().to_string();
    let data0 = parts[0].data.clone();
    let site0 = std::thread::spawn(move || {
        let net = SiteNet::over(Box::new(l0.accept(&timeouts()).unwrap()));
        dsc::site::session(&net, &data0, None, SessionLimits::default(), |_| {}).unwrap()
    });

    // site 1: registers for the first run, then "crashes" on receiving the
    // work order; a second accept serves the re-dialed session properly
    let l1 = SiteListener::bind("127.0.0.1:0").unwrap();
    let addr1 = l1.local_addr().unwrap().to_string();
    let data1 = parts[1].data.clone();
    let site1 = std::thread::spawn(move || {
        {
            let net = SiteNet::over(Box::new(l1.accept(&timeouts()).unwrap()));
            match net.recv().unwrap() {
                Message::RunStart { run } => net
                    .send(&Message::RunSiteInfo {
                        run,
                        site: 1,
                        n_points: data1.len() as u64,
                        dim: data1.dim as u32,
                    })
                    .unwrap(),
                other => panic!("expected a run open, got {other:?}"),
            }
            let _ = net.recv().unwrap(); // the work order arrives …
            // … and the connection dies mid-run (simulated crash)
        }
        let net = SiteNet::over(Box::new(l1.accept(&timeouts()).unwrap()));
        dsc::site::session(&net, &data1, None, SessionLimits::default(), |_| {}).unwrap()
    });

    let mut cfg = cfg_with_seed(0);
    cfg.net.sites = vec![addr0, addr1];
    let opts = ServerOpts {
        max_jobs: 1, // job B must queue behind job A
        queue_depth: 8,
        allow_label_pull: true,
        client_limit: Some(2),
        ..Default::default()
    };
    let client_listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let leader_addr = client_listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn({
        let cfg = cfg.clone();
        let opts = opts.clone();
        move || serve_jobs(&cfg, &opts, client_listener).unwrap()
    });

    let client_a = JobClient::connect(&leader_addr, &timeouts()).unwrap();
    let client_b = JobClient::connect(&leader_addr, &timeouts()).unwrap();
    let run_a = client_a.submit(&spec).unwrap();
    let run_b = client_b.submit(&spec).unwrap();
    assert_ne!(run_a, run_b);

    // run A dies with site 1's connection; only A is affected
    let err = client_a.await_done(run_a).unwrap_err();
    assert!(format!("{err:#}").contains("site 1"), "{err:#}");

    // run B drains from the queue onto the re-dialed link and completes,
    // with full parity against the channel pipeline
    let report_b = client_b.await_done(run_b).unwrap();
    let labels_b = pull_global(&client_b, run_b, &report_b, &parts);
    assert_eq!(labels_b, base.labels);

    drop(client_a);
    drop(client_b);
    let stats = server.join().unwrap();
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.failed, 1);

    let out0 = site0.join().unwrap();
    assert_eq!(out0.runs_served, 1, "site 0 completed only run B");
    assert_eq!(out0.aborted_runs, 1, "run A was left open on site 0");
    let out1 = site1.join().unwrap();
    assert_eq!(out1.runs_served, 1);
}
