//! The job-serving leader, end to end over real loopback sockets: two
//! concurrent jobs interleaving over shared persistent site sessions, with
//! per-run byte/label parity against (a) the same jobs run sequentially
//! through the server and (b) the in-process channel pipeline; a mid-run
//! site death failing only the affected run while the queue drains onto a
//! re-dialed link; and the label-pull policy gate.
//! (`examples/tcp_cluster.rs` re-proves the headline flow with separate OS
//! processes.)

use std::time::Duration;

use dsc::config::PipelineConfig;
use dsc::coordinator::server::{serve_jobs, JobClient, ServerOpts, ServerStats};
use dsc::coordinator::{run_pipeline, spec_from_config};
use dsc::data::gmm;
use dsc::data::scenario::{self, Scenario, SitePart};
use dsc::net::tcp::{SiteListener, TcpTimeouts};
use dsc::net::{JobReport, JobSpec, Message, SiteNet};
use dsc::spectral::Bandwidth;

fn timeouts() -> TcpTimeouts {
    TcpTimeouts {
        connect: Duration::from_secs(5),
        io: Duration::from_secs(10),
        max_idle: Duration::ZERO,
    }
}

fn workload() -> (dsc::data::Dataset, Vec<SitePart>) {
    let ds = gmm::paper_mixture_10d(2_000, 0.1, 21);
    let parts = scenario::split(&ds, Scenario::D3, 2, 21);
    (ds, parts)
}

fn cfg_with_seed(seed: u64) -> PipelineConfig {
    PipelineConfig {
        total_codes: 64,
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed,
        ..Default::default()
    }
}

/// One job's result as a client saw it: the leader's report plus the
/// pulled per-point labels assembled into the global vector.
struct ServedJob {
    report: JobReport,
    labels: Vec<u16>,
}

fn pull_global(
    client: &JobClient,
    run: u32,
    report: &JobReport,
    parts: &[SitePart],
) -> Vec<u16> {
    let per_site = client.pull_labels(run, report.per_site.len()).unwrap();
    let total: usize = parts.iter().map(|p| p.data.len()).sum();
    let mut labels = vec![0u16; total];
    for (site, ls) in per_site {
        let part = &parts[site];
        assert_eq!(ls.len(), part.data.len(), "site {site} label count");
        for (local, &g) in part.global_idx.iter().enumerate() {
            labels[g as usize] = ls[local];
        }
    }
    labels
}

/// Stand up persistent site sessions + a job server, push `specs` through
/// it (all submitted up front when `concurrent`, else strictly one after
/// another), pull every run's labels, and tear everything down cleanly.
fn serve_and_submit(
    parts: &[SitePart],
    specs: &[JobSpec],
    concurrent: bool,
) -> (Vec<ServedJob>, ServerStats) {
    let mut addrs = Vec::new();
    let mut site_threads = Vec::new();
    for part in parts {
        let listener = SiteListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        let data = part.data.clone();
        site_threads.push(std::thread::spawn(move || {
            let conn = listener.accept(&timeouts()).unwrap();
            assert!(conn.session_mode(), "a job server must open sessions");
            let net = SiteNet::over(Box::new(conn));
            // one persistent session serves every run of this test
            dsc::site::session(&net, &data, None, |_| {}).unwrap()
        }));
    }

    let mut cfg = cfg_with_seed(0);
    cfg.net.sites = addrs;
    let opts = ServerOpts {
        max_jobs: if concurrent { specs.len().max(1) } else { 1 },
        queue_depth: 8,
        allow_label_pull: true,
        client_limit: Some(specs.len() as u64),
    };
    let client_listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let leader_addr = client_listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn({
        let cfg = cfg.clone();
        let opts = opts.clone();
        move || serve_jobs(&cfg, &opts, client_listener).unwrap()
    });

    let mut served = Vec::new();
    if concurrent {
        // every job in flight before any result is awaited
        let clients: Vec<JobClient> =
            specs.iter().map(|_| JobClient::connect(&leader_addr, &timeouts()).unwrap()).collect();
        let runs: Vec<u32> =
            clients.iter().zip(specs).map(|(c, s)| c.submit(s).unwrap()).collect();
        for (client, run) in clients.iter().zip(&runs) {
            let report = client.await_done(*run).unwrap();
            let labels = pull_global(client, *run, &report, parts);
            served.push(ServedJob { report, labels });
        }
        drop(clients); // disconnect: lets the server reach its client_limit
    } else {
        for spec in specs {
            let client = JobClient::connect(&leader_addr, &timeouts()).unwrap();
            let run = client.submit(spec).unwrap();
            let report = client.await_done(run).unwrap();
            let labels = pull_global(&client, run, &report, parts);
            served.push(ServedJob { report, labels });
        }
    }
    let stats = server.join().unwrap();
    // the server dropping its site links ends every session cleanly
    for t in site_threads {
        let outcome = t.join().unwrap();
        assert_eq!(outcome.aborted_runs, 0);
    }
    (served, stats)
}

/// The acceptance headline: two jobs submitted concurrently to one leader
/// complete with labels and per-link counters identical to running them
/// sequentially — and identical labels to the in-process channel pipeline,
/// with each site's shard served from one session (loaded exactly once).
#[test]
fn concurrent_jobs_match_sequential_and_channel() {
    let (_ds, parts) = workload();
    let spec_a = spec_from_config(&cfg_with_seed(21));
    let spec_b = spec_from_config(&cfg_with_seed(77));
    let specs = [spec_a, spec_b];

    let base_a = run_pipeline(&parts, &cfg_with_seed(21)).unwrap();
    let base_b = run_pipeline(&parts, &cfg_with_seed(77)).unwrap();

    let (concurrent, stats_c) = serve_and_submit(&parts, &specs, true);
    let (sequential, stats_s) = serve_and_submit(&parts, &specs, false);
    assert_eq!(stats_c.completed, 2);
    assert_eq!(stats_c.failed, 0);
    assert_eq!(stats_s.completed, 2);

    for (i, base) in [&base_a, &base_b].into_iter().enumerate() {
        // labels: concurrent == sequential == the channel pipeline
        assert_eq!(concurrent[i].labels, base.labels, "job {i} vs channel");
        assert_eq!(concurrent[i].labels, sequential[i].labels, "job {i} concurrency");

        // per-run, per-link counters: byte-for-byte across interleavings
        let (c, s) = (&concurrent[i].report, &sequential[i].report);
        assert_eq!(c.n_codes, s.n_codes, "job {i} codes");
        assert_eq!(c.sigma, s.sigma, "job {i} sigma");
        assert_eq!(c.per_site, s.per_site, "job {i} per-link counters");

        // the run-scoped dialect is exactly 2 frames up (registration +
        // codebook) and 3 down (run open + work order + labels) per site
        for (sid, l) in c.per_site.iter().enumerate() {
            assert_eq!(l.up_frames, 2, "job {i} site {sid} up frames");
            assert_eq!(l.down_frames, 3, "job {i} site {sid} down frames");
        }
        assert_eq!(c.n_codes as usize, base.n_codes, "job {i} codes vs channel");
    }
    // two different seeds really are two different clusterings of the
    // same data (guards against comparing a job with itself)
    assert_ne!(concurrent[0].labels, concurrent[1].labels);
}

/// A site dying mid-run fails only the run that was in flight: the queued
/// job behind it is served after the leader re-dials the restarted site,
/// over the surviving site's original session.
#[test]
fn site_death_fails_one_run_and_the_queue_drains() {
    let (_ds, parts) = workload();
    let spec = spec_from_config(&cfg_with_seed(21));
    let base = run_pipeline(&parts, &cfg_with_seed(21)).unwrap();

    // site 0: one healthy persistent session for the whole test
    let l0 = SiteListener::bind("127.0.0.1:0").unwrap();
    let addr0 = l0.local_addr().unwrap().to_string();
    let data0 = parts[0].data.clone();
    let site0 = std::thread::spawn(move || {
        let net = SiteNet::over(Box::new(l0.accept(&timeouts()).unwrap()));
        dsc::site::session(&net, &data0, None, |_| {}).unwrap()
    });

    // site 1: registers for the first run, then "crashes" on receiving the
    // work order; a second accept serves the re-dialed session properly
    let l1 = SiteListener::bind("127.0.0.1:0").unwrap();
    let addr1 = l1.local_addr().unwrap().to_string();
    let data1 = parts[1].data.clone();
    let site1 = std::thread::spawn(move || {
        {
            let net = SiteNet::over(Box::new(l1.accept(&timeouts()).unwrap()));
            match net.recv().unwrap() {
                Message::RunStart { run } => net
                    .send(&Message::RunSiteInfo {
                        run,
                        site: 1,
                        n_points: data1.len() as u64,
                        dim: data1.dim as u32,
                    })
                    .unwrap(),
                other => panic!("expected a run open, got {other:?}"),
            }
            let _ = net.recv().unwrap(); // the work order arrives …
            // … and the connection dies mid-run (simulated crash)
        }
        let net = SiteNet::over(Box::new(l1.accept(&timeouts()).unwrap()));
        dsc::site::session(&net, &data1, None, |_| {}).unwrap()
    });

    let mut cfg = cfg_with_seed(0);
    cfg.net.sites = vec![addr0, addr1];
    let opts = ServerOpts {
        max_jobs: 1, // job B must queue behind job A
        queue_depth: 8,
        allow_label_pull: true,
        client_limit: Some(2),
    };
    let client_listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let leader_addr = client_listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn({
        let cfg = cfg.clone();
        let opts = opts.clone();
        move || serve_jobs(&cfg, &opts, client_listener).unwrap()
    });

    let client_a = JobClient::connect(&leader_addr, &timeouts()).unwrap();
    let client_b = JobClient::connect(&leader_addr, &timeouts()).unwrap();
    let run_a = client_a.submit(&spec).unwrap();
    let run_b = client_b.submit(&spec).unwrap();
    assert_ne!(run_a, run_b);

    // run A dies with site 1's connection; only A is affected
    let err = client_a.await_done(run_a).unwrap_err();
    assert!(format!("{err:#}").contains("site 1"), "{err:#}");

    // run B drains from the queue onto the re-dialed link and completes,
    // with full parity against the channel pipeline
    let report_b = client_b.await_done(run_b).unwrap();
    let labels_b = pull_global(&client_b, run_b, &report_b, &parts);
    assert_eq!(labels_b, base.labels);

    drop(client_a);
    drop(client_b);
    let stats = server.join().unwrap();
    assert_eq!(stats.completed, 1);
    assert_eq!(stats.failed, 1);

    let out0 = site0.join().unwrap();
    assert_eq!(out0.runs_served, 1, "site 0 completed only run B");
    assert_eq!(out0.aborted_runs, 1, "run A was left open on site 0");
    let out1 = site1.join().unwrap();
    assert_eq!(out1.runs_served, 1);
}

/// A hostile or buggy job spec is refused at submit time with a reason —
/// it must never reach the central step, where `k = 0` would panic the
/// reactor and take every client's runs down with it.
#[test]
fn hostile_spec_is_rejected_at_submit() {
    let ds = gmm::paper_mixture_10d(400, 0.1, 51);
    let parts = scenario::split(&ds, Scenario::D3, 1, 51);

    let listener = SiteListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let data = parts[0].data.clone();
    let site = std::thread::spawn(move || {
        let net = SiteNet::over(Box::new(listener.accept(&timeouts()).unwrap()));
        dsc::site::session(&net, &data, None, |_| {}).unwrap()
    });

    let mut cfg = cfg_with_seed(51);
    cfg.net.sites = vec![addr];
    let opts = ServerOpts {
        max_jobs: 1,
        queue_depth: 2,
        allow_label_pull: false,
        client_limit: Some(1),
    };
    let client_listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let leader_addr = client_listener.local_addr().unwrap().to_string();
    let server = std::thread::spawn({
        let cfg = cfg.clone();
        let opts = opts.clone();
        move || serve_jobs(&cfg, &opts, client_listener).unwrap()
    });

    let client = JobClient::connect(&leader_addr, &timeouts()).unwrap();
    let mut bad = spec_from_config(&cfg_with_seed(51));
    bad.k_clusters = 0;
    let err = client.submit(&bad).unwrap_err();
    assert!(format!("{err:#}").contains("bad job spec"), "{err:#}");

    // the connection (and the server) survive the refusal
    let run = client.submit(&spec_from_config(&cfg_with_seed(51))).unwrap();
    client.await_done(run).unwrap();
    drop(client);

    let stats = server.join().unwrap();
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.completed, 1);
    let outcome = site.join().unwrap();
    assert_eq!(outcome.runs_served, 1);
}

/// `[leader] allow_label_pull` gates the pull plane; an unknown run is
/// refused with a reason even when pulls are allowed.
#[test]
fn label_pull_policy_is_enforced() {
    let ds = gmm::paper_mixture_10d(600, 0.1, 33);
    let parts = scenario::split(&ds, Scenario::D3, 1, 33);
    let spec = spec_from_config(&cfg_with_seed(33));

    for allow in [false, true] {
        let listener = SiteListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let data = parts[0].data.clone();
        let site = std::thread::spawn(move || {
            let net = SiteNet::over(Box::new(listener.accept(&timeouts()).unwrap()));
            dsc::site::session(&net, &data, None, |_| {}).unwrap()
        });

        let mut cfg = cfg_with_seed(33);
        cfg.net.sites = vec![addr];
        let opts = ServerOpts {
            max_jobs: 1,
            queue_depth: 2,
            allow_label_pull: allow,
            client_limit: Some(1),
        };
        let client_listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let leader_addr = client_listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn({
            let cfg = cfg.clone();
            let opts = opts.clone();
            move || serve_jobs(&cfg, &opts, client_listener).unwrap()
        });

        let client = JobClient::connect(&leader_addr, &timeouts()).unwrap();
        let run = client.submit(&spec).unwrap();
        let report = client.await_done(run).unwrap();
        if allow {
            let err = client.pull_labels(9999, 1).unwrap_err();
            assert!(format!("{err:#}").contains("not a completed run"), "{err:#}");
            let pulled = client.pull_labels(run, report.per_site.len()).unwrap();
            assert_eq!(pulled.len(), 1);
            assert_eq!(pulled[0].1.len(), parts[0].data.len());
        } else {
            let err = client.pull_labels(run, report.per_site.len()).unwrap_err();
            assert!(format!("{err:#}").contains("disabled"), "{err:#}");
        }
        drop(client);
        let stats = server.join().unwrap();
        assert_eq!(stats.completed, 1);
        site.join().unwrap();
    }
}
