//! Warm-standby failover: the kill-point sweep.
//!
//! The canonical three-tenant DRR mix from `journal_replay.rs` (one run
//! stalled until the straggler deadline, one central gated) is executed
//! once uninterrupted, then once per journal record index K with the
//! primary reactor killed the moment its journal holds K records. Instead
//! of restarting the same process, each kill promotes a **warm standby**:
//! the primary's journal is replicated record by record through the real
//! `JREPLRECORD` wire codec into a second journal file, the copy is
//! checked byte-identical, and [`ChannelHarness::crash_and_failover`]
//! resumes the reactor from the *standby's* journal — replay, re-attach
//! to the surviving world, keep serving the still-unprocessed mailbox.
//! Every client-visible outcome — accepted run ids, queue positions and
//! ETAs, failure texts, reports with per-link byte counters, pulled
//! labels — plus the durable queue pop order must equal the uninterrupted
//! twin's, bit for bit, at **every** K. CI runs this file under
//! `DSC_THREADS=1` and `=4` alongside the crash-restart sweep;
//! `examples/failover.rs` re-proves the flow over TCP with a SIGKILLed
//! primary process.

mod common;

use std::fs;
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use common::pull_global;
use dsc::config::PipelineConfig;
use dsc::coordinator::harness::{
    serve_channel_journaled, ChannelLink, HarnessOpts, HarnessTicker,
};
use dsc::coordinator::journal::{recover, JournalEvent};
use dsc::coordinator::server::{JobClient, ServerOpts};
use dsc::coordinator::{run_pipeline, spec_from_config};
use dsc::data::gmm;
use dsc::data::scenario::{self, Scenario, SitePart};
use dsc::data::Dataset;
use dsc::net::channel::Fault;
use dsc::net::{JobSpec, LinkReport};
use dsc::spectral::Bandwidth;

fn workload() -> Vec<SitePart> {
    // Small on purpose: the sweep re-runs the whole mix once per record.
    let ds = gmm::paper_mixture_10d(600, 0.1, 21);
    scenario::split(&ds, Scenario::D3, 2, 21)
}

fn datasets(parts: &[SitePart]) -> Vec<Dataset> {
    parts.iter().map(|p| p.data.clone()).collect()
}

fn cfg_with_seed(seed: u64) -> PipelineConfig {
    PipelineConfig {
        total_codes: 32,
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed,
        ..Default::default()
    }
}

fn spec(seed: u64, priority: u32) -> JobSpec {
    let mut spec = spec_from_config(&cfg_with_seed(seed));
    spec.priority = priority;
    spec
}

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dsc-fo-{}-{tag}.journal", std::process::id()))
}

/// Two-phase central gate (same shape as `journal_replay.rs`): the worker
/// announces it entered run 2's central, then blocks until the script
/// opens it.
struct Gate {
    entered: Mutex<bool>,
    entered_cv: Condvar,
    open: Mutex<bool>,
    open_cv: Condvar,
}

impl Gate {
    fn new() -> Arc<Gate> {
        Arc::new(Gate {
            entered: Mutex::new(false),
            entered_cv: Condvar::new(),
            open: Mutex::new(false),
            open_cv: Condvar::new(),
        })
    }

    fn enter_and_wait(&self) {
        *self.entered.lock().unwrap() = true;
        self.entered_cv.notify_all();
        let mut open = self.open.lock().unwrap();
        while !*open {
            open = self.open_cv.wait(open).unwrap();
        }
    }

    fn wait_entered(&self) {
        let mut entered = self.entered.lock().unwrap();
        while !*entered {
            entered = self.entered_cv.wait(entered).unwrap();
        }
    }

    fn open(&self) {
        *self.open.lock().unwrap() = true;
        self.open_cv.notify_all();
    }
}

/// Everything a client of the mix can observe, in one `PartialEq` bundle
/// (`central_ns` deliberately absent — it is real compute wall time, the
/// one nondeterministic field a report carries).
#[derive(Debug, PartialEq)]
struct Outcome {
    run1: u32,
    err1: String,
    /// `(run, position, eta_ns)` of the four tracked accepts, send order.
    tracked: Vec<(u32, u32, u64)>,
    run6: u32,
    /// `(run, n_codes, sigma, wall_ns, per_site)` per completed run.
    reports: Vec<(u32, u32, f64, u64, Vec<LinkReport>)>,
    /// `(run, global labels)` per completed run.
    labels: Vec<(u32, Vec<u16>)>,
}

/// The canonical three-tenant mix (identical to `journal_replay.rs`, so
/// the two sweeps prove restart and failover over the same history).
fn drive_script(
    clients: Vec<JobClient<ChannelLink>>,
    ticker: HarnessTicker,
    gate: Arc<Gate>,
    parts: Arc<Vec<SitePart>>,
) -> Outcome {
    let mut clients = clients.into_iter();
    let (a, b, c) = (
        clients.next().unwrap(),
        clients.next().unwrap(),
        clients.next().unwrap(),
    );
    let run1 = a.submit(&spec(21, JobSpec::DEFAULT_PRIORITY)).unwrap();
    let b1 = b.submit_tracked(&spec(33, 2)).unwrap();
    let c1 = c.submit_tracked(&spec(55, 4)).unwrap();
    let b2 = b.submit_tracked(&spec(34, 2)).unwrap();
    let c2 = c.submit_tracked(&spec(56, 4)).unwrap();
    let run6 = a.submit(&spec(22, JobSpec::DEFAULT_PRIORITY)).unwrap();

    // Past run 1's collect deadline: it fails, freeing the single job slot
    // for the DRR backlog built up above.
    ticker.tick(Duration::from_secs(6));
    let err1 = format!("{:#}", a.await_done(run1).unwrap_err());

    // Run 2's central really blocked once, then history may flow.
    gate.wait_entered();
    gate.open();

    let mut reports = Vec::new();
    let mut labels = Vec::new();
    for (client, run) in
        [(&b, b1.run), (&c, c1.run), (&b, b2.run), (&c, c2.run), (&a, run6)]
    {
        let report = client.await_done(run).unwrap();
        labels.push((run, pull_global(client, run, &report, &parts)));
        reports.push((run, report.n_codes, report.sigma, report.wall_ns, report.per_site));
    }
    drop((a, b, c)); // all three tenants gone: the server may shut down
    Outcome {
        run1,
        err1,
        tracked: vec![
            (b1.run, b1.position, b1.eta_ns),
            (c1.run, c1.position, c1.eta_ns),
            (b2.run, b2.position, b2.eta_ns),
            (c2.run, c2.position, c2.eta_ns),
        ],
        run6,
        reports,
        labels,
    }
}

fn mix_cfg() -> PipelineConfig {
    let mut cfg = cfg_with_seed(0);
    cfg.collect_timeout = Duration::from_secs(5); // virtual seconds
    cfg.leader.fair_queue = true;
    cfg
}

fn mix_opts(gate: &Arc<Gate>) -> HarnessOpts {
    let hook = {
        let gate = Arc::clone(gate);
        Arc::new(move |run: u32| {
            if run == 2 {
                gate.enter_and_wait();
            }
        })
    };
    HarnessOpts {
        server: ServerOpts {
            max_jobs: 1,
            queue_depth: 8,
            allow_label_pull: true,
            central_workers: 1,
            client_limit: Some(3),
        },
        faults: vec![
            Fault::DropRunFrames { site: 0, run: 1 },
            Fault::DropRunFrames { site: 1, run: 1 },
        ],
        central_hook: Some(hook),
        hangups: vec![],
    }
}

/// What one full execution of the mix left behind, harvested from the
/// journal the *surviving* reactor wrote (the standby's copy after a
/// failover, the primary's when the run was uninterrupted).
struct Executed {
    outcome: Outcome,
    stats: (u64, u64, u64),
    sessions: Vec<(usize, usize)>,
    /// Queue pop order, from the durable `Started` annotations.
    started: Vec<u32>,
    admitted: Vec<u32>,
    finished: Vec<(u32, bool)>,
    records: u64,
}

/// Run the mix once. With `kill_after = Some(k)`, the primary is killed
/// at its K-record crash point and the warm standby (journaling into
/// `standby_path`) is promoted in its place.
fn execute(
    parts: &Arc<Vec<SitePart>>,
    primary_path: &PathBuf,
    standby_path: &PathBuf,
    kill_after: Option<u64>,
) -> Executed {
    let _ = fs::remove_file(primary_path);
    let _ = fs::remove_file(standby_path);
    let gate = Gate::new();
    let mut harness = serve_channel_journaled(
        datasets(parts),
        &mix_cfg(),
        mix_opts(&gate),
        primary_path,
        kill_after,
    )
    .unwrap();
    let clients = vec![harness.client(), harness.client(), harness.client()];
    let ticker = harness.ticker();
    let script = {
        let parts = Arc::clone(parts);
        let gate = Arc::clone(&gate);
        std::thread::spawn(move || drive_script(clients, ticker, gate, parts))
    };
    if kill_after.is_some() {
        // Blocks until the primary dies mid-script, then replicates its
        // journal into the standby (real JREPL codec, byte-identity
        // checked inside) and promotes the standby reactor against the
        // surviving world.
        harness.crash_and_failover(standby_path).unwrap();
    }
    let outcome = script.join().expect("script thread panicked");
    let (stats, outcomes) = harness.join().unwrap();

    let survivor = if kill_after.is_some() { standby_path } else { primary_path };
    if kill_after.is_some() {
        // The dead primary's journal is frozen at the kill point; the
        // promoted standby started from a byte-identical copy and only
        // appended — so the primary's file is a byte-prefix of the
        // standby's.
        let primary = fs::read(primary_path).unwrap();
        let standby = fs::read(standby_path).unwrap();
        assert!(
            standby.len() >= primary.len() && standby[..primary.len()] == primary[..],
            "the dead primary's journal must be a byte-prefix of the standby's"
        );
    }
    let recovered = recover(survivor).unwrap();
    assert!(!recovered.torn, "a synced journal must not have a torn tail");
    let mut started = Vec::new();
    let mut admitted = Vec::new();
    let mut finished = Vec::new();
    for rec in &recovered.records {
        match rec.event {
            JournalEvent::Started { run } => started.push(run),
            JournalEvent::Admitted { run, .. } => admitted.push(run),
            JournalEvent::Completed { run } => finished.push((run, true)),
            JournalEvent::Failed { run } => finished.push((run, false)),
            _ => {}
        }
    }
    Executed {
        outcome,
        stats: (stats.completed, stats.failed, stats.rejected),
        sessions: outcomes.iter().map(|o| (o.runs_served, o.aborted_runs)).collect(),
        started,
        admitted,
        finished,
        records: recovered.records.len() as u64,
    }
}

/// The headline: killing the primary at **every** journal record index K
/// and promoting the warm standby yields the uninterrupted execution —
/// labels, per-link byte counters, queue pop order, and every
/// client-visible reply, bit for bit.
#[test]
fn failover_sweep_promotes_bit_identically() {
    let parts = Arc::new(workload());
    let primary = temp_path("primary");
    let standby = temp_path("standby");

    let reference = execute(&parts, &primary, &standby, None);
    // Anchor the reference against the in-process pipeline: replication
    // and promotion are not allowed to change what a job computes.
    let base = run_pipeline(&parts, &cfg_with_seed(33)).unwrap();
    let run2_labels =
        &reference.outcome.labels.iter().find(|(run, _)| *run == 2).unwrap().1;
    assert_eq!(run2_labels, &base.labels, "reference run 2 vs pipeline");
    assert_eq!(reference.stats, (5, 1, 0));
    assert_eq!(reference.admitted, vec![1, 2, 3, 4, 5, 6]);
    assert!(reference.records > 0);

    for k in 1..=reference.records {
        let promoted = execute(&parts, &primary, &standby, Some(k));
        assert_eq!(promoted.outcome, reference.outcome, "kill at record {k}");
        assert_eq!(promoted.stats, reference.stats, "kill at record {k}: stats");
        assert_eq!(
            promoted.sessions, reference.sessions,
            "kill at record {k}: site sessions"
        );
        assert_eq!(
            promoted.started, reference.started,
            "kill at record {k}: queue pop order"
        );
        assert_eq!(promoted.admitted, reference.admitted, "kill at record {k}");
        assert_eq!(promoted.finished, reference.finished, "kill at record {k}");
        assert_eq!(
            promoted.records, reference.records,
            "kill at record {k}: journal length"
        );
    }
    let _ = fs::remove_file(&primary);
    let _ = fs::remove_file(&standby);
}
