//! Helpers shared by the job-server test suites (included via
//! `mod common;` — not a test binary of its own).

use dsc::coordinator::server::{ClientLink, JobClient};
use dsc::data::scenario::SitePart;
use dsc::net::JobReport;

/// Pull a completed run's per-site labels through the leader and scatter
/// them into the global label vector via each part's `global_idx`.
/// Generic over the client link, so the TCP and channel suites assemble
/// labels identically.
pub fn pull_global<L: ClientLink>(
    client: &JobClient<L>,
    run: u32,
    report: &JobReport,
    parts: &[SitePart],
) -> Vec<u16> {
    let per_site = client.pull_labels(run, report.per_site.len()).unwrap();
    let total: usize = parts.iter().map(|p| p.data.len()).sum();
    let mut labels = vec![0u16; total];
    for (site, ls) in per_site {
        let part = &parts[site];
        assert_eq!(ls.len(), part.data.len(), "site {site} label count");
        for (local, &g) in part.global_idx.iter().enumerate() {
            labels[g as usize] = ls[local];
        }
    }
    labels
}
