//! Leader crash recovery over real processes: SIGKILL mid-run, replay,
//! resume.
//!
//! `examples/tcp_cluster.rs` proves the job server's happy path across OS
//! processes; this example proves the crash path the run journal
//! (`dsc leader --serve --journal`) exists for:
//!
//! 1. run the workload **in-process** — the uninterrupted twin whose
//!    labels the recovered service must reproduce exactly;
//! 2. spawn two persistent `dsc site` daemons and a journaling
//!    `dsc leader --serve --journal J`, submit a job, and **SIGKILL the
//!    leader** while the run is in flight — the submitting client's
//!    connection dies with it;
//! 3. restart the leader against the **same journal**: it replays the
//!    log, re-dials the surviving site daemons, and restarts the orphaned
//!    run from its journaled spec;
//! 4. a **fresh** client pulls the resumed run's labels through the new
//!    leader (label pulls are not owner-scoped) and asserts them
//!    identical to the twin's, and the journal itself must hold the
//!    original submit plus the restart marker.
//!
//! CI runs this as a blocking smoke step. It needs the `dsc` binary:
//!
//! ```bash
//! cargo build --release && cargo run --release --example crash_recovery
//! ```
//!
//! (`DSC_BIN=/path/to/dsc` overrides binary discovery.)

use std::io::{BufRead, BufReader, Read as _};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};
use dsc::coordinator::journal::{recover, JournalEvent};
use dsc::coordinator::server::JobClient;
use dsc::coordinator::spec_from_config;
use dsc::data::csvio;
use dsc::prelude::*;

const SITES: usize = 2;
const SEED: u64 = 11;

/// Kills the child on drop so a failed assertion never leaves daemon
/// processes behind.
struct ChildGuard {
    child: Child,
    name: &'static str,
}

impl ChildGuard {
    fn wait(&mut self) -> Result<()> {
        let status = self.child.wait().with_context(|| format!("wait for {}", self.name))?;
        if !status.success() {
            bail!("{} exited with {status}", self.name);
        }
        Ok(())
    }

    /// The point of the exercise: SIGKILL, no warning, no flush.
    fn kill(&mut self) -> Result<()> {
        self.child.kill().with_context(|| format!("kill {}", self.name))?;
        self.child.wait().with_context(|| format!("reap {}", self.name))?;
        Ok(())
    }
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Locate the `dsc` binary next to this example (`target/<profile>/dsc`).
fn dsc_bin() -> Result<PathBuf> {
    if let Some(p) = std::env::var_os("DSC_BIN") {
        return Ok(PathBuf::from(p));
    }
    let exe = std::env::current_exe().context("current_exe")?;
    let profile_dir = exe
        .parent() // …/examples
        .and_then(Path::parent) // …/<profile>
        .ok_or_else(|| anyhow!("cannot locate target dir from {}", exe.display()))?;
    let bin = profile_dir.join(format!("dsc{}", std::env::consts::EXE_SUFFIX));
    if !bin.exists() {
        bail!(
            "{} not found — build the CLI first (`cargo build --release`) or set DSC_BIN",
            bin.display()
        );
    }
    Ok(bin)
}

/// Spawn a persistent `dsc site` daemon, parse its `LISTENING <addr>`
/// banner, and keep its stdout drained.
fn spawn_site(bin: &Path, csv: &Path, s: usize) -> Result<(ChildGuard, String)> {
    let mut child = Command::new(bin)
        .arg("site")
        .args(["--listen", "127.0.0.1:0"])
        .args(["--data", csv.to_str().unwrap()])
        .stdout(Stdio::piped())
        .spawn()
        .with_context(|| format!("spawn site {s}"))?;
    let stdout = child.stdout.take().expect("piped stdout");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    reader.read_line(&mut line).context("read site banner")?;
    let addr = line
        .trim()
        .strip_prefix("LISTENING ")
        .ok_or_else(|| anyhow!("site {s} printed {line:?}, expected LISTENING <addr>"))?
        .to_string();
    println!("site {s}: pid {} listening on {addr} (persistent)", child.id());
    // keep draining the pipe so the child can never block on a full one
    std::thread::spawn(move || {
        let mut sink = String::new();
        while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
            sink.clear();
        }
    });
    Ok((ChildGuard { child, name: "dsc site" }, addr))
}

/// Spawn a journaling job-serving leader and parse its `SERVING <addr>`
/// banner; the rest of its stdout keeps draining into the returned join
/// handle.
fn spawn_leader(
    bin: &Path,
    sites: &str,
    config: &Path,
    journal: &Path,
    serve_limit: Option<u64>,
) -> Result<(ChildGuard, String, std::thread::JoinHandle<String>)> {
    let mut cmd = Command::new(bin);
    cmd.arg("leader")
        .args(["--sites", sites])
        .args(["--serve", "127.0.0.1:0"])
        .args(["--journal", journal.to_str().unwrap()])
        .args(["--config", config.to_str().unwrap()]);
    if let Some(n) = serve_limit {
        cmd.args(["--serve-limit", &n.to_string()]);
    }
    let mut child = cmd.stdout(Stdio::piped()).spawn().context("spawn job-serving leader")?;
    let stdout = child.stdout.take().expect("piped stdout");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    reader.read_line(&mut line).context("read leader banner")?;
    let addr = line
        .trim()
        .strip_prefix("SERVING ")
        .ok_or_else(|| anyhow!("leader printed {line:?}, expected SERVING <addr>"))?
        .to_string();
    println!("leader: pid {} serving jobs on {addr}", child.id());
    let rest = std::thread::spawn(move || {
        let mut rest = String::new();
        let _ = reader.read_to_string(&mut rest);
        rest
    });
    Ok((ChildGuard { child, name: "dsc leader --serve" }, addr, rest))
}

fn main() -> Result<()> {
    let bin = dsc_bin()?;

    // ── the uninterrupted twin: in-process, channel transport ───────────
    let ds = dsc::data::gmm::paper_mixture_10d(6_000, 0.1, SEED);
    let parts = scenario::split(&ds, Scenario::D3, SITES, SEED);
    let cfg = PipelineConfig {
        total_codes: 150,
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed: SEED,
        ..Default::default()
    };
    println!("=== uninterrupted twin: in-process run ===");
    let base = run_pipeline(&parts, &cfg)?;
    println!("twin: accuracy {:.4}, {} codewords", base.accuracy, base.n_codes);

    // ── stage shards + configs + the journal path ───────────────────────
    let dir = std::env::temp_dir().join(format!("dsc_crash_recovery_{}", std::process::id()));
    std::fs::create_dir_all(&dir).context("create scratch dir")?;
    let mut csvs = Vec::new();
    for part in &parts {
        let csv = dir.join(format!("site{}.csv", part.site_id));
        csvio::save_dataset(&csv, &part.data, &["crash_recovery example shard"])?;
        csvs.push(csv);
    }
    let server_toml = dir.join("server.toml");
    std::fs::write(
        &server_toml,
        "[pipeline]\ncollect_timeout_s = 120\n\n[leader]\nallow_label_pull = true\n",
    )
    .context("write server config")?;
    let journal = dir.join("leader.journal");

    // ── two persistent site daemons; they outlive both leaders ──────────
    println!("\n=== crash run: {SITES} persistent sites + journaling leader ===");
    let mut site_guards = Vec::new();
    let mut addrs = Vec::new();
    for (s, csv) in csvs.iter().enumerate() {
        let (guard, addr) = spawn_site(&bin, csv, s)?;
        site_guards.push(guard);
        addrs.push(addr);
    }
    let sites_arg = addrs.join(",");

    // ── leader #1: submit, then SIGKILL it mid-run ──────────────────────
    let (mut leader1, serve_addr, rest1) =
        spawn_leader(&bin, &sites_arg, &server_toml, &journal, None)?;
    let timeouts = cfg.net.tcp_timeouts();
    let client1 = JobClient::connect(&serve_addr, &timeouts).context("connect client 1")?;
    let accepted = client1.submit_tracked(&spec_from_config(&cfg))?;
    println!("client 1: run {} accepted — killing the leader", accepted.run);
    // Give the run a moment to get on the wire (the journal syncs at every
    // mailbox drain, so the accepted submit is long since on disk), then
    // kill -9. Whether the central finished in time or not, replay must
    // converge on the same labels.
    std::thread::sleep(Duration::from_millis(300));
    leader1.kill()?;
    drop(rest1); // pipe closed by the kill; the drain thread just ends
    drop(client1); // its connection died with the leader

    // ── leader #2: same journal, same sites — replay and resume ─────────
    println!("\n=== recovery: restart the leader against the same journal ===");
    let (mut leader2, serve_addr, rest2) =
        spawn_leader(&bin, &sites_arg, &server_toml, &journal, Some(1))?;

    // One fresh client (it is the whole --serve-limit): pull the resumed
    // run's labels, retrying while the run is still being recomputed.
    let client2 = JobClient::connect(&serve_addr, &timeouts).context("connect client 2")?;
    let mut pulled = None;
    for _ in 0..200 {
        match client2.pull_labels(accepted.run, SITES) {
            Ok(p) => {
                pulled = Some(p);
                break;
            }
            Err(e) if format!("{e:#}").contains("not a completed run") => {
                std::thread::sleep(Duration::from_millis(150));
            }
            Err(e) => return Err(e.context("pull resumed run's labels")),
        }
    }
    let pulled = pulled
        .ok_or_else(|| anyhow!("run {} never completed on the restarted leader", accepted.run))?;
    drop(client2);
    leader2.wait()?;
    let rest = rest2.join().expect("leader stdout thread");
    if !rest.contains("SERVED_JOBS completed=1") {
        bail!("restarted leader did not report the resumed run as completed:\n{rest}");
    }

    // ── the resumed run must equal the uninterrupted twin, exactly ──────
    let mut labels = vec![0u16; ds.len()];
    for (site, site_labels) in &pulled {
        let part = &parts[*site];
        if site_labels.len() != part.data.len() {
            bail!(
                "site {site}: pulled {} labels for {} points",
                site_labels.len(),
                part.data.len()
            );
        }
        for (local, &g) in part.global_idx.iter().enumerate() {
            labels[g as usize] = site_labels[local];
        }
    }
    if labels != base.labels {
        let diverged = labels.iter().zip(&base.labels).filter(|(a, b)| a != b).count();
        bail!(
            "resumed run diverges from the uninterrupted twin: {diverged}/{} labels differ",
            ds.len()
        );
    }
    println!("resumed run labels: identical to the uninterrupted twin ✓");
    let accuracy = clustering_accuracy(&ds.labels, &labels);
    println!("accuracy (recovered service): {accuracy:.4}");
    if accuracy < 0.9 {
        bail!("recovered accuracy {accuracy:.4} below the 0.9 quickstart floor");
    }

    // ── and the journal must tell the story ─────────────────────────────
    let log = recover(&journal)?;
    let submits =
        log.records.iter().filter(|r| matches!(r.event, JournalEvent::ClientSubmit { .. })).count();
    let restarts =
        log.records.iter().filter(|r| matches!(r.event, JournalEvent::Restart)).count();
    if submits != 1 || restarts != 1 {
        bail!(
            "journal should hold exactly the original submit and one restart marker, \
             got {submits} submits / {restarts} restarts in {} records",
            log.records.len()
        );
    }
    println!("journal: {} records, 1 submit, 1 restart marker ✓", log.records.len());

    drop(site_guards); // kill the persistent daemons
    std::fs::remove_dir_all(&dir).ok();
    println!("\ncrash_recovery: the leader died and nobody lost a run");
    Ok(())
}
