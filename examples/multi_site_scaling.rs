//! Multi-site scaling (the paper's §5.2.1 / Table 6 shape): HEPMASS proxy
//! with 2, 3 and 4 distributed sites, both DMLs.
//!
//! Expected shape (paper): accuracy flat as sites increase; elapsed time
//! keeps dropping but with diminishing returns, because the central
//! spectral step — which does not parallelize across sites — starts to
//! dominate. The printed "central share" column makes that mechanism
//! visible directly.
//!
//! ```bash
//! cargo run --release --offline --example multi_site_scaling
//! ```

use anyhow::Result;
use dsc::bench::Table;
use dsc::data::uci_proxy;
use dsc::dml::DmlKind;
use dsc::prelude::*;

fn main() -> Result<()> {
    let spec = uci_proxy::by_name("hepmass").unwrap();
    let n = std::env::var("DSC_N").ok().and_then(|v| v.parse().ok()).unwrap_or(40_000);
    let ds = spec.generate(n, 21);
    println!(
        "HEPMASS proxy: n={} dim={} classes={} codewords={}",
        ds.len(),
        ds.dim,
        ds.n_classes,
        spec.target_codewords()
    );

    let mut table = Table::new(
        "HEPMASS proxy, multi-site scaling (paper Table 6 protocol)",
        &["dml", "sites", "scenario", "accuracy", "elapsed_s", "central_share", "max_dml_s"],
    );

    for dml in [DmlKind::KMeans, DmlKind::RpTree] {
        let cfg = PipelineConfig {
            dml,
            total_codes: spec.target_codewords().min(n / 8),
            k_clusters: spec.n_classes,
            bandwidth: Bandwidth::MedianScale(0.75),
            seed: 23,
            ..Default::default()
        };
        // non-distributed reference row
        let base = run_pipeline(
            &[SitePart {
                site_id: 0,
                data: ds.clone(),
                global_idx: (0..ds.len() as u32).collect(),
            }],
            &cfg,
        )?;
        table.row(&[
            format!("{dml}"),
            "1".into(),
            "—".into(),
            format!("{:.4}", base.accuracy),
            format!("{:.3}", base.elapsed_model.as_secs_f64()),
            format!(
                "{:.0}%",
                100.0 * base.central.as_secs_f64() / base.elapsed_model.as_secs_f64().max(1e-9)
            ),
            format!("{:.3}", base.site_dml[0].as_secs_f64()),
        ]);

        for sites in [2, 3, 4] {
            for sc in [Scenario::D1, Scenario::D2, Scenario::D3] {
                let parts = scenario::split(&ds, sc, sites, 29);
                let r = run_pipeline(&parts, &cfg)?;
                let max_dml =
                    r.site_dml.iter().copied().max().unwrap_or_default().as_secs_f64();
                table.row(&[
                    format!("{dml}"),
                    sites.to_string(),
                    sc.to_string(),
                    format!("{:.4}", r.accuracy),
                    format!("{:.3}", r.elapsed_model.as_secs_f64()),
                    format!(
                        "{:.0}%",
                        100.0 * r.central.as_secs_f64()
                            / r.elapsed_model.as_secs_f64().max(1e-9)
                    ),
                    format!("{max_dml:.3}"),
                ]);
            }
        }
    }
    print!("{}", table.render());
    let path = table.save_csv("multi_site_scaling")?;
    println!("\nwrote {}", path.display());
    Ok(())
}
