//! Warm-standby leader failover over real processes: SIGKILL the primary
//! mid-run, let the standby promote, pull identical labels from it.
//!
//! `examples/crash_recovery.rs` proves the journal survives a leader that
//! *restarts in place*; this example proves the replicated path the
//! standby mode (`dsc leader --serve --standby`) exists for — recovery
//! with **no shared disk**, on a different process holding its own copy
//! of the journal:
//!
//! 1. run the workload **in-process** — the uninterrupted twin whose
//!    labels the promoted standby must reproduce exactly;
//! 2. spawn two persistent `dsc site` daemons, a journaling primary
//!    (`dsc leader --serve --journal P`), and a warm standby replicating
//!    that journal over the job socket into its own file
//!    (`--standby --primary <addr> --journal S`);
//! 3. submit a job to the primary and **SIGKILL the primary** while the
//!    run is in flight — the submitting client's connection dies with it;
//! 4. the standby's replication link goes silent past `--standby-timeout`,
//!    so it promotes: replays its replicated journal, re-dials the
//!    surviving site daemons, restarts the orphaned run, and binds its
//!    own job socket (`PROMOTED` then `SERVING` on stdout);
//! 5. a **fresh** client pulls the resumed run's labels through the
//!    promoted standby and asserts them identical to the twin's, and the
//!    standby's journal must hold the replicated submit plus the
//!    promotion's restart marker.
//!
//! CI runs this as a blocking smoke step. It needs the `dsc` binary:
//!
//! ```bash
//! cargo build --release && cargo run --release --example failover
//! ```
//!
//! (`DSC_BIN=/path/to/dsc` overrides binary discovery.)

use std::io::{BufRead, BufReader};
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdout, Command, Stdio};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};
use dsc::coordinator::journal::{recover, JournalEvent};
use dsc::coordinator::server::JobClient;
use dsc::coordinator::spec_from_config;
use dsc::data::csvio;
use dsc::prelude::*;

const SITES: usize = 2;
const SEED: u64 = 23;
/// Replication-link silence that triggers promotion. Short, so the
/// example stays fast; the primary heartbeats at a quarter of it, so a
/// *live* primary is never mistaken for a dead one.
const STANDBY_TIMEOUT_S: &str = "2";

/// Kills the child on drop so a failed assertion never leaves daemon
/// processes behind.
struct ChildGuard {
    child: Child,
    name: &'static str,
}

impl ChildGuard {
    fn wait(&mut self) -> Result<()> {
        let status = self.child.wait().with_context(|| format!("wait for {}", self.name))?;
        if !status.success() {
            bail!("{} exited with {status}", self.name);
        }
        Ok(())
    }

    /// The point of the exercise: SIGKILL, no warning, no flush.
    fn kill(&mut self) -> Result<()> {
        self.child.kill().with_context(|| format!("kill {}", self.name))?;
        self.child.wait().with_context(|| format!("reap {}", self.name))?;
        Ok(())
    }
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Locate the `dsc` binary next to this example (`target/<profile>/dsc`).
fn dsc_bin() -> Result<PathBuf> {
    if let Some(p) = std::env::var_os("DSC_BIN") {
        return Ok(PathBuf::from(p));
    }
    let exe = std::env::current_exe().context("current_exe")?;
    let profile_dir = exe
        .parent() // …/examples
        .and_then(Path::parent) // …/<profile>
        .ok_or_else(|| anyhow!("cannot locate target dir from {}", exe.display()))?;
    let bin = profile_dir.join(format!("dsc{}", std::env::consts::EXE_SUFFIX));
    if !bin.exists() {
        bail!(
            "{} not found — build the CLI first (`cargo build --release`) or set DSC_BIN",
            bin.display()
        );
    }
    Ok(bin)
}

/// Spawn a persistent `dsc site` daemon, parse its `LISTENING <addr>`
/// banner, and keep its stdout drained.
fn spawn_site(bin: &Path, csv: &Path, s: usize) -> Result<(ChildGuard, String)> {
    let mut child = Command::new(bin)
        .arg("site")
        .args(["--listen", "127.0.0.1:0"])
        .args(["--data", csv.to_str().unwrap()])
        .stdout(Stdio::piped())
        .spawn()
        .with_context(|| format!("spawn site {s}"))?;
    let stdout = child.stdout.take().expect("piped stdout");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    reader.read_line(&mut line).context("read site banner")?;
    let addr = line
        .trim()
        .strip_prefix("LISTENING ")
        .ok_or_else(|| anyhow!("site {s} printed {line:?}, expected LISTENING <addr>"))?
        .to_string();
    println!("site {s}: pid {} listening on {addr} (persistent)", child.id());
    // keep draining the pipe so the child can never block on a full one
    std::thread::spawn(move || {
        let mut sink = String::new();
        while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
            sink.clear();
        }
    });
    Ok((ChildGuard { child, name: "dsc site" }, addr))
}

/// Spawn the journaling primary and parse its `SERVING <addr>` banner.
fn spawn_primary(
    bin: &Path,
    sites: &str,
    config: &Path,
    journal: &Path,
) -> Result<(ChildGuard, String)> {
    let mut child = Command::new(bin)
        .arg("leader")
        .args(["--sites", sites])
        .args(["--serve", "127.0.0.1:0"])
        .args(["--journal", journal.to_str().unwrap()])
        .args(["--config", config.to_str().unwrap()])
        .stdout(Stdio::piped())
        .spawn()
        .context("spawn primary leader")?;
    let stdout = child.stdout.take().expect("piped stdout");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    reader.read_line(&mut line).context("read primary banner")?;
    let addr = line
        .trim()
        .strip_prefix("SERVING ")
        .ok_or_else(|| anyhow!("primary printed {line:?}, expected SERVING <addr>"))?
        .to_string();
    println!("primary: pid {} serving jobs on {addr}", child.id());
    std::thread::spawn(move || {
        let mut sink = String::new();
        while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
            sink.clear();
        }
    });
    Ok((ChildGuard { child, name: "dsc leader --serve (primary)" }, addr))
}

/// Spawn the warm standby and parse its `STANDBY …` banner. Its stdout
/// reader is returned: the `PROMOTED` / `SERVING` lines only appear after
/// the primary dies, so the caller reads them when the time comes.
fn spawn_standby(
    bin: &Path,
    sites: &str,
    config: &Path,
    primary_addr: &str,
    journal: &Path,
) -> Result<(ChildGuard, BufReader<ChildStdout>)> {
    let mut child = Command::new(bin)
        .arg("leader")
        .args(["--sites", sites])
        .args(["--serve", "127.0.0.1:0"])
        .arg("--standby")
        .args(["--primary", primary_addr])
        .args(["--standby-timeout", STANDBY_TIMEOUT_S])
        .args(["--journal", journal.to_str().unwrap()])
        .args(["--serve-limit", "1"])
        .args(["--config", config.to_str().unwrap()])
        .stdout(Stdio::piped())
        .spawn()
        .context("spawn standby leader")?;
    let stdout = child.stdout.take().expect("piped stdout");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    reader.read_line(&mut line).context("read standby banner")?;
    if !line.trim().starts_with("STANDBY ") {
        bail!("standby printed {line:?}, expected STANDBY primary=…");
    }
    println!("standby: pid {} replicating from {primary_addr}", child.id());
    Ok((ChildGuard { child, name: "dsc leader --serve --standby" }, reader))
}

/// Block until the promoted standby prints `SERVING <addr>`, checking the
/// `PROMOTED records=…` line comes first.
fn await_promotion(reader: &mut BufReader<ChildStdout>) -> Result<String> {
    let mut promoted = false;
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line).context("read standby stdout")? == 0 {
            bail!("standby exited before promoting");
        }
        let line = line.trim();
        if line.starts_with("PROMOTED ") {
            println!("standby: {line}");
            promoted = true;
        } else if let Some(addr) = line.strip_prefix("SERVING ") {
            if !promoted {
                bail!("standby printed SERVING before PROMOTED — it must never serve unpromoted");
            }
            return Ok(addr.to_string());
        }
    }
}

fn main() -> Result<()> {
    let bin = dsc_bin()?;

    // ── the uninterrupted twin: in-process, channel transport ───────────
    let ds = dsc::data::gmm::paper_mixture_10d(6_000, 0.1, SEED);
    let parts = scenario::split(&ds, Scenario::D3, SITES, SEED);
    let cfg = PipelineConfig {
        total_codes: 150,
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed: SEED,
        ..Default::default()
    };
    println!("=== uninterrupted twin: in-process run ===");
    let base = run_pipeline(&parts, &cfg)?;
    println!("twin: accuracy {:.4}, {} codewords", base.accuracy, base.n_codes);

    // ── stage shards + configs + the two journal paths ──────────────────
    let dir = std::env::temp_dir().join(format!("dsc_failover_{}", std::process::id()));
    std::fs::create_dir_all(&dir).context("create scratch dir")?;
    let mut csvs = Vec::new();
    for part in &parts {
        let csv = dir.join(format!("site{}.csv", part.site_id));
        csvio::save_dataset(&csv, &part.data, &["failover example shard"])?;
        csvs.push(csv);
    }
    let server_toml = dir.join("server.toml");
    std::fs::write(
        &server_toml,
        "[pipeline]\ncollect_timeout_s = 120\n\n[leader]\nallow_label_pull = true\n",
    )
    .context("write server config")?;
    let primary_journal = dir.join("primary.journal");
    let standby_journal = dir.join("standby.journal");

    // ── two persistent site daemons; they outlive the primary ───────────
    println!("\n=== failover run: {SITES} persistent sites + primary + warm standby ===");
    let mut site_guards = Vec::new();
    let mut addrs = Vec::new();
    for (s, csv) in csvs.iter().enumerate() {
        let (guard, addr) = spawn_site(&bin, csv, s)?;
        site_guards.push(guard);
        addrs.push(addr);
    }
    let sites_arg = addrs.join(",");

    // ── primary + standby, then a job, then SIGKILL the primary ─────────
    let (mut primary, primary_addr) =
        spawn_primary(&bin, &sites_arg, &server_toml, &primary_journal)?;
    let (mut standby, mut standby_out) =
        spawn_standby(&bin, &sites_arg, &server_toml, &primary_addr, &standby_journal)?;
    // Let the replication link establish before the submit exists, so the
    // record stream (not just catch-up) is exercised.
    std::thread::sleep(Duration::from_millis(400));

    let timeouts = cfg.net.tcp_timeouts();
    let client1 = JobClient::connect(&primary_addr, &timeouts).context("connect client 1")?;
    let accepted = client1.submit_tracked(&spec_from_config(&cfg))?;
    println!("client 1: run {} accepted — killing the primary", accepted.run);
    // Give the group commit a moment to ship the submit to the standby
    // (sync first, then replicate — the standby never leads the disk),
    // then kill -9 mid-run.
    std::thread::sleep(Duration::from_millis(300));
    primary.kill()?;
    drop(client1); // its connection died with the primary

    // ── the standby notices the silence and promotes ────────────────────
    println!("\n=== promotion: standby takes over after {STANDBY_TIMEOUT_S}s of silence ===");
    let standby_addr = await_promotion(&mut standby_out)?;
    println!("standby: serving jobs on {standby_addr}");
    std::thread::spawn(move || {
        let mut sink = String::new();
        while matches!(standby_out.read_line(&mut sink), Ok(n) if n > 0) {
            sink.clear();
        }
    });

    // One fresh client (it is the whole --serve-limit): pull the resumed
    // run's labels, retrying while the run is still being recomputed.
    let client2 = JobClient::connect(&standby_addr, &timeouts).context("connect client 2")?;
    let mut pulled = None;
    for _ in 0..200 {
        match client2.pull_labels(accepted.run, SITES) {
            Ok(p) => {
                pulled = Some(p);
                break;
            }
            Err(e) if format!("{e:#}").contains("not a completed run") => {
                std::thread::sleep(Duration::from_millis(150));
            }
            Err(e) => return Err(e.context("pull resumed run's labels")),
        }
    }
    let pulled = pulled.ok_or_else(|| {
        anyhow!("run {} never completed on the promoted standby", accepted.run)
    })?;
    drop(client2);
    standby.wait()?;

    // ── the resumed run must equal the uninterrupted twin, exactly ──────
    let mut labels = vec![0u16; ds.len()];
    for (site, site_labels) in &pulled {
        let part = &parts[*site];
        if site_labels.len() != part.data.len() {
            bail!(
                "site {site}: pulled {} labels for {} points",
                site_labels.len(),
                part.data.len()
            );
        }
        for (local, &g) in part.global_idx.iter().enumerate() {
            labels[g as usize] = site_labels[local];
        }
    }
    if labels != base.labels {
        let diverged = labels.iter().zip(&base.labels).filter(|(a, b)| a != b).count();
        bail!(
            "promoted standby diverges from the uninterrupted twin: {diverged}/{} labels differ",
            ds.len()
        );
    }
    println!("promoted standby's labels: identical to the uninterrupted twin ✓");
    let accuracy = clustering_accuracy(&ds.labels, &labels);
    println!("accuracy (promoted standby): {accuracy:.4}");
    if accuracy < 0.9 {
        bail!("promoted accuracy {accuracy:.4} below the 0.9 quickstart floor");
    }

    // ── and the standby's journal must tell the story ───────────────────
    let log = recover(&standby_journal)?;
    let submits =
        log.records.iter().filter(|r| matches!(r.event, JournalEvent::ClientSubmit { .. })).count();
    let restarts =
        log.records.iter().filter(|r| matches!(r.event, JournalEvent::Restart)).count();
    if submits != 1 || restarts != 1 {
        bail!(
            "standby journal should hold the replicated submit and the promotion's restart \
             marker, got {submits} submits / {restarts} restarts in {} records",
            log.records.len()
        );
    }
    println!(
        "standby journal: {} records, 1 replicated submit, 1 promotion restart ✓",
        log.records.len()
    );

    drop(site_guards); // kill the persistent daemons
    std::fs::remove_dir_all(&dir).ok();
    println!("\nfailover: the primary died and the standby finished its work");
    Ok(())
}
