//! Privacy audit — the paper's §6 claim that "as the transmitted data are
//! not in their original form, data privacy may also be preserved".
//!
//! This driver inspects exactly what crosses the wire under both DMLs and
//! reports:
//!
//! * whether any transmitted codeword *is* an original point (exact hit);
//! * the distribution of distances from each codeword to its nearest
//!   original point (a codeword sitting on top of a point leaks it);
//! * the minimum group size (a k-anonymity-style floor: a codeword
//!   averaging one point IS that point).
//!
//! The audit makes the paper's caveat concrete: K-means codewords with
//! group size 1 do leak single points, so deployments wanting privacy
//! should enforce a minimum leaf/cluster size — which this binary measures.
//!
//! ```bash
//! cargo run --release --offline --example privacy_audit
//! ```

use anyhow::Result;
use dsc::bench::Table;
use dsc::data::gmm;
use dsc::dml::{self, DmlKind, DmlParams};
use dsc::prelude::*;

fn main() -> Result<()> {
    let ds = gmm::paper_mixture_10d(20_000, 0.3, 31);
    let parts = scenario::split(&ds, Scenario::D2, 2, 31);

    let mut table = Table::new(
        "What leaves a site: codeword-to-data proximity audit",
        &["dml", "site", "codes", "exact_hits", "min_nn_dist", "med_nn_dist", "min_group", "groups=1"],
    );

    for dml in [DmlKind::KMeans, DmlKind::RpTree] {
        for part in &parts {
            let params = DmlParams {
                kind: dml,
                target_codes: 250,
                max_iters: 30,
                tol: 1e-6,
                seed: 37 + part.site_id as u64,
            };
            let cb = dml::apply(&part.data, &params);

            // nearest original point per codeword
            let mut exact_hits = 0usize;
            let mut nn_dists: Vec<f64> = Vec::with_capacity(cb.n_codes());
            for c in 0..cb.n_codes() {
                let cw = cb.codeword(c);
                let mut best = f64::INFINITY;
                for i in 0..part.data.len() {
                    let p = part.data.point(i);
                    let d2: f64 = cw
                        .iter()
                        .zip(p)
                        .map(|(a, b)| ((a - b) as f64).powi(2))
                        .sum();
                    best = best.min(d2);
                }
                let d = best.sqrt();
                if d == 0.0 {
                    exact_hits += 1;
                }
                nn_dists.push(d);
            }
            nn_dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let min_nn = nn_dists.first().copied().unwrap_or(0.0);
            let med_nn = nn_dists[nn_dists.len() / 2];
            let min_group = cb.weights.iter().min().copied().unwrap_or(0);
            let singletons = cb.weights.iter().filter(|&&w| w == 1).count();

            table.row(&[
                dml.to_string(),
                part.site_id.to_string(),
                cb.n_codes().to_string(),
                exact_hits.to_string(),
                format!("{min_nn:.4}"),
                format!("{med_nn:.4}"),
                min_group.to_string(),
                singletons.to_string(),
            ]);
        }
    }
    print!("{}", table.render());
    println!(
        "\nReading the table: `exact_hits` > 0 or `groups=1` > 0 would mean raw points leak \
         verbatim; positive nearest-neighbour distances show transmitted codewords are \
         averages, not originals. Enforce a minimum group size for a k-anonymity floor."
    );
    let path = table.save_csv("privacy_audit")?;
    println!("wrote {}", path.display());
    Ok(())
}
