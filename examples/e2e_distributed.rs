//! End-to-end driver (DESIGN.md §4): the full system exercised on a real
//! small workload, proving all three layers compose.
//!
//! Part A — **real data**: Fisher's Iris (embedded, 150×4, 3 classes),
//! split across 2 sites, clustered through the **XLA backend** so the run
//! traverses Rust coordinator → simulated network → PJRT-compiled HLO
//! (with the Pallas affinity kernel inside) → label population.
//!
//! Part B — **paper-scale synthetic**: the §5.1 10-D mixture, 40 000
//! points, compression 40:1 (1000 codewords), all three scenarios and both
//! DMLs, distributed vs non-distributed — the headline comparison of
//! Figs. 6–7 in one run. Results land in `bench_out/e2e_summary.csv` and
//! are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example e2e_distributed
//! ```

use anyhow::Result;
use dsc::bench::Table;
use dsc::data::{gmm, iris};
use dsc::dml::DmlKind;
use dsc::prelude::*;

fn nondistributed(ds: &Dataset) -> Vec<SitePart> {
    vec![SitePart { site_id: 0, data: ds.clone(), global_idx: (0..ds.len() as u32).collect() }]
}

fn main() -> Result<()> {
    // ── Part A: real data through the full three-layer stack ────────────
    println!("=== Part A: Iris (real data), 2 sites, XLA backend ===");
    let ds = iris::load();
    let parts = scenario::split(&ds, Scenario::D3, 2, 3);
    let cfg = PipelineConfig {
        total_codes: 40,
        k_clusters: 3,
        algo: Algo::Njw,
        bandwidth: Bandwidth::EigengapSearch { k: 3 },
        backend: if std::path::Path::new("artifacts/manifest.json").exists() {
            Backend::Xla
        } else {
            eprintln!("(artifacts missing — falling back to native backend)");
            Backend::Native
        },
        seed: 5,
        ..Default::default()
    };
    let report = run_pipeline(&parts, &cfg)?;
    println!(
        "iris: accuracy {:.4} | ARI {:.4} | NMI {:.4} | {} codewords | σ {:.3} | {} B on wire",
        report.accuracy,
        report.ari,
        report.nmi,
        report.n_codes,
        report.sigma,
        report.net.total_bytes()
    );
    assert!(report.accuracy > 0.80, "iris sanity floor");

    // ── Part B: the paper's synthetic workload at full spec ─────────────
    println!("\n=== Part B: 10-D mixture, 40k points, 1000 codewords (40:1) ===");
    let mut table = Table::new(
        "Distributed vs non-distributed (paper Figs. 6–7 protocol, ρ = 0.3)",
        &["dml", "setting", "accuracy", "gap", "elapsed_s", "wire_bytes"],
    );

    let ds = gmm::paper_mixture_10d(40_000, 0.3, 11);
    for dml in [DmlKind::KMeans, DmlKind::RpTree] {
        let cfg = PipelineConfig {
            dml,
            total_codes: 1000,
            k_clusters: 4,
            bandwidth: Bandwidth::MedianScale(0.5),
            seed: 13,
            ..Default::default()
        };
        let base = run_pipeline(&nondistributed(&ds), &cfg)?;
        table.row(&[
            dml.to_string(),
            "non-distributed".into(),
            format!("{:.4}", base.accuracy),
            "—".into(),
            format!("{:.3}", base.elapsed_model.as_secs_f64()),
            "0".into(),
        ]);
        for sc in [Scenario::D1, Scenario::D2, Scenario::D3] {
            let parts = scenario::split(&ds, sc, 2, 17);
            let r = run_pipeline(&parts, &cfg)?;
            table.row(&[
                dml.to_string(),
                sc.to_string(),
                format!("{:.4}", r.accuracy),
                format!("{:+.4}", r.accuracy - base.accuracy),
                format!("{:.3}", r.elapsed_model.as_secs_f64()),
                r.net.total_bytes().to_string(),
            ]);
        }
    }
    print!("{}", table.render());
    let path = table.save_csv("e2e_summary")?;
    println!("\nwrote {}", path.display());
    Ok(())
}
