//! Multi-process TCP cluster: leader + N site daemons as real OS processes.
//!
//! The proof that the TCP transport is the same protocol as the in-process
//! star, not a lookalike:
//!
//! 1. run the quickstart workload (paper 10-D GMM, D3 split, 2 sites,
//!    40:1 compression) **in-process** over the channel transport;
//! 2. write each site's shard to CSV, spawn one `dsc site` **process** per
//!    shard plus one `dsc leader` **process**, all on localhost;
//! 3. assert the TCP run produced **identical labels** and **byte-for-byte
//!    identical per-link `NetReport` counters**, and that accuracy ≥ 0.9;
//! 4. restart the sites as **persistent daemons**, start one
//!    `dsc leader --serve` job server against them, and push **two
//!    concurrent `dsc submit` jobs** through it — asserting both complete,
//!    the job matching step 1's config reproduces its labels exactly
//!    (pulled back through the leader via `LABELS_PULL`), and each site
//!    served both runs over a single session;
//! 5. restart the sites with `--ingest` (an extra tranche of the same
//!    mixture, `[site] report_digest = true` so the `SITEINFO2` digest
//!    frame rides the real TCP handshake), push a third submit through a
//!    fresh job server, and assert every original **and** ingested point
//!    comes back labelled — with the run-scoped frame counts unchanged,
//!    because the digest frame is session-scoped.
//!
//! CI runs this as a blocking smoke step. It needs the `dsc` binary:
//!
//! ```bash
//! cargo build --release && cargo run --release --example tcp_cluster
//! ```
//!
//! (`DSC_BIN=/path/to/dsc` overrides binary discovery.)

use std::io::{BufRead, BufReader, Read as _};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};

use anyhow::{anyhow, bail, Context, Result};
use dsc::data::csvio;
use dsc::prelude::*;

const SITES: usize = 2;
const SEED: u64 = 7;

/// Kills the child on drop so a failed assertion never leaves daemon
/// processes behind.
struct ChildGuard {
    child: Child,
    name: &'static str,
}

impl ChildGuard {
    fn wait(&mut self) -> Result<()> {
        let status = self.child.wait().with_context(|| format!("wait for {}", self.name))?;
        if !status.success() {
            bail!("{} exited with {status}", self.name);
        }
        Ok(())
    }
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Locate the `dsc` binary next to this example (`target/<profile>/dsc`).
fn dsc_bin() -> Result<PathBuf> {
    if let Some(p) = std::env::var_os("DSC_BIN") {
        return Ok(PathBuf::from(p));
    }
    let exe = std::env::current_exe().context("current_exe")?;
    let profile_dir = exe
        .parent() // …/examples
        .and_then(Path::parent) // …/<profile>
        .ok_or_else(|| anyhow!("cannot locate target dir from {}", exe.display()))?;
    let bin = profile_dir.join(format!("dsc{}", std::env::consts::EXE_SUFFIX));
    if !bin.exists() {
        bail!(
            "{} not found — build the CLI first (`cargo build --release`) or set DSC_BIN",
            bin.display()
        );
    }
    Ok(bin)
}

/// One parsed `NETREPORT site=…` line from the leader's stdout.
#[derive(Debug, Default, PartialEq)]
struct LinkCounters {
    up_frames: u64,
    up_bytes: u64,
    down_frames: u64,
    down_bytes: u64,
    up_sim_ns: u128,
    down_sim_ns: u128,
}

fn parse_netreports(stdout: &str) -> Result<Vec<(usize, LinkCounters)>> {
    let mut out = Vec::new();
    for line in stdout.lines() {
        let Some(rest) = line.trim().strip_prefix("NETREPORT site=") else { continue };
        let mut fields = rest.split_whitespace();
        let site: usize = fields.next().unwrap_or("").parse().context("NETREPORT site id")?;
        let mut c = LinkCounters::default();
        for kv in fields {
            let (k, v) = kv.split_once('=').ok_or_else(|| anyhow!("bad NETREPORT field {kv:?}"))?;
            match k {
                "up_frames" => c.up_frames = v.parse()?,
                "up_bytes" => c.up_bytes = v.parse()?,
                "down_frames" => c.down_frames = v.parse()?,
                "down_bytes" => c.down_bytes = v.parse()?,
                "up_sim_ns" => c.up_sim_ns = v.parse()?,
                "down_sim_ns" => c.down_sim_ns = v.parse()?,
                other => bail!("unknown NETREPORT field {other:?}"),
            }
        }
        out.push((site, c));
    }
    if out.is_empty() {
        bail!("leader printed no NETREPORT lines:\n{stdout}");
    }
    Ok(out)
}

fn main() -> Result<()> {
    let bin = dsc_bin()?;

    // ── the workload: quickstart GMM, identical to the in-process smoke ──
    let ds = dsc::data::gmm::paper_mixture_10d(12_000, 0.1, SEED);
    let parts = scenario::split(&ds, Scenario::D3, SITES, SEED);
    let cfg = PipelineConfig {
        total_codes: 300, // 40:1, the paper's ratio
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed: SEED,
        ..Default::default()
    };

    println!("=== reference run: in-process channel transport ===");
    let base = run_pipeline(&parts, &cfg)?;
    println!(
        "in-process: accuracy {:.4}, {} codewords, {} B on the wire",
        base.accuracy,
        base.n_codes,
        base.net.total_bytes()
    );

    // ── stage the shards + config on disk ───────────────────────────────
    let dir = std::env::temp_dir().join(format!("dsc_tcp_cluster_{}", std::process::id()));
    std::fs::create_dir_all(&dir).context("create scratch dir")?;
    let mut csvs = Vec::new();
    let mut label_files = Vec::new();
    for part in &parts {
        let csv = dir.join(format!("site{}.csv", part.site_id));
        csvio::save_dataset(&csv, &part.data, &["tcp_cluster example shard"])?;
        label_files.push(dir.join(format!("labels{}.txt", part.site_id)));
        csvs.push(csv);
    }
    // Must describe the exact same pipeline as `cfg` above — parity of
    // labels and byte counters depends on it.
    let toml_path = dir.join("leader.toml");
    std::fs::write(
        &toml_path,
        format!(
            "[pipeline]\ntotal_codes = 300\nk_clusters = 4\nseed = {SEED}\n\
             collect_timeout_s = 120\n\n[bandwidth]\npolicy = \"median\"\nvalue = 0.5\n"
        ),
    )
    .context("write leader config")?;

    // ── spawn one `dsc site` process per shard ──────────────────────────
    println!("\n=== multi-process run: {SITES} `dsc site` + 1 `dsc leader` ===");
    let mut site_guards = Vec::new();
    let mut addrs = Vec::new();
    for s in 0..SITES {
        let mut child = Command::new(&bin)
            .arg("site")
            .args(["--listen", "127.0.0.1:0"])
            .args(["--data", csvs[s].to_str().unwrap()])
            .args(["--out", label_files[s].to_str().unwrap()])
            .arg("--once")
            .stdout(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawn site {s}"))?;
        // The site prints `LISTENING <addr>` once its socket is bound —
        // with port 0 that line is the only way to learn the port.
        let stdout = child.stdout.take().expect("piped stdout");
        let mut reader = BufReader::new(stdout);
        let mut line = String::new();
        reader.read_line(&mut line).context("read site banner")?;
        let addr = line
            .trim()
            .strip_prefix("LISTENING ")
            .ok_or_else(|| anyhow!("site {s} printed {line:?}, expected LISTENING <addr>"))?
            .to_string();
        println!("site {s}: pid {} listening on {addr}", child.id());
        addrs.push(addr);
        // keep draining the pipe so the child can never block on a full one
        std::thread::spawn(move || {
            let mut sink = String::new();
            while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
                sink.clear();
            }
        });
        site_guards.push(ChildGuard { child, name: "dsc site" });
    }

    // ── run the leader process against them ─────────────────────────────
    let leader_out = Command::new(&bin)
        .arg("leader")
        .args(["--sites", &addrs.join(",")])
        .args(["--config", toml_path.to_str().unwrap()])
        .output()
        .context("run dsc leader")?;
    let stdout = String::from_utf8_lossy(&leader_out.stdout).into_owned();
    print!("{stdout}");
    if !leader_out.status.success() {
        bail!(
            "leader exited with {}:\n{}",
            leader_out.status,
            String::from_utf8_lossy(&leader_out.stderr)
        );
    }
    for g in &mut site_guards {
        g.wait()?;
    }

    // ── parity: per-link counters must match byte for byte ──────────────
    let reports = parse_netreports(&stdout)?;
    if reports.len() != SITES {
        bail!("expected {SITES} NETREPORT lines, got {}", reports.len());
    }
    for (site, tcp) in &reports {
        let b = &base.net.per_site[*site];
        let expect = LinkCounters {
            up_frames: b.to_leader.frames,
            up_bytes: b.to_leader.bytes,
            down_frames: b.to_site.frames,
            down_bytes: b.to_site.bytes,
            up_sim_ns: b.to_leader.sim_time.as_nanos(),
            down_sim_ns: b.to_site.sim_time.as_nanos(),
        };
        if *tcp != expect {
            bail!("site {site} counters diverge:\n  tcp     {tcp:?}\n  channel {expect:?}");
        }
    }
    println!("per-link NetReport counters: identical across transports ✓");

    // ── parity: labels must be identical, and accurate ───────────────────
    let mut tcp_labels = vec![0u16; ds.len()];
    for (s, part) in parts.iter().enumerate() {
        let site_labels = dsc::site::read_labels(&label_files[s])?;
        if site_labels.len() != part.data.len() {
            bail!(
                "site {s} wrote {} labels for {} points",
                site_labels.len(),
                part.data.len()
            );
        }
        for (local, &g) in part.global_idx.iter().enumerate() {
            tcp_labels[g as usize] = site_labels[local];
        }
    }
    if tcp_labels != base.labels {
        let diverged = tcp_labels
            .iter()
            .zip(&base.labels)
            .filter(|(a, b)| a != b)
            .count();
        bail!("label parity failed: {diverged}/{} labels differ across transports", ds.len());
    }
    println!("labels: identical across transports ✓");

    let accuracy = clustering_accuracy(&ds.labels, &tcp_labels);
    println!("accuracy (multi-process): {accuracy:.4}");
    if accuracy < 0.9 {
        bail!("multi-process accuracy {accuracy:.4} below the 0.9 quickstart floor");
    }

    // ── phase 2: job server — 2 concurrent `dsc submit` jobs ────────────
    println!("\n=== job server: 2 persistent sites + `dsc leader --serve` + 2 × `dsc submit` ===");

    // fresh persistent site daemons (phase 1's exited after --once)
    let mut site_guards = Vec::new();
    let mut addrs = Vec::new();
    for s in 0..SITES {
        let mut child = Command::new(&bin)
            .arg("site")
            .args(["--listen", "127.0.0.1:0"])
            .args(["--data", csvs[s].to_str().unwrap()])
            .stdout(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawn persistent site {s}"))?;
        let stdout = child.stdout.take().expect("piped stdout");
        let mut reader = BufReader::new(stdout);
        let mut line = String::new();
        reader.read_line(&mut line).context("read site banner")?;
        let addr = line
            .trim()
            .strip_prefix("LISTENING ")
            .ok_or_else(|| anyhow!("site {s} printed {line:?}, expected LISTENING <addr>"))?
            .to_string();
        println!("site {s}: pid {} listening on {addr} (persistent)", child.id());
        addrs.push(addr);
        std::thread::spawn(move || {
            let mut sink = String::new();
            while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
                sink.clear();
            }
        });
        site_guards.push(ChildGuard { child, name: "dsc site" });
    }

    // the job server: exits cleanly once both submit clients are done
    let server_toml = dir.join("server.toml");
    std::fs::write(
        &server_toml,
        "[pipeline]\ncollect_timeout_s = 120\n\n[leader]\nmax_jobs = 2\n\
         allow_label_pull = true\n",
    )
    .context("write server config")?;
    let mut leader_child = Command::new(&bin)
        .arg("leader")
        .args(["--sites", &addrs.join(",")])
        .args(["--serve", "127.0.0.1:0"])
        .args(["--serve-limit", "2"])
        .args(["--config", server_toml.to_str().unwrap()])
        .stdout(Stdio::piped())
        .spawn()
        .context("spawn job-serving leader")?;
    let leader_stdout = leader_child.stdout.take().expect("piped stdout");
    let mut leader_reader = BufReader::new(leader_stdout);
    let mut line = String::new();
    leader_reader.read_line(&mut line).context("read leader banner")?;
    let serve_addr = line
        .trim()
        .strip_prefix("SERVING ")
        .ok_or_else(|| anyhow!("leader printed {line:?}, expected SERVING <addr>"))?
        .to_string();
    println!("leader: pid {} serving jobs on {serve_addr}", leader_child.id());
    let leader_rest = std::thread::spawn(move || {
        let mut rest = String::new();
        let _ = leader_reader.read_to_string(&mut rest);
        rest
    });
    let mut leader_guard = ChildGuard { child: leader_child, name: "dsc leader --serve" };

    // job 1 reuses phase 1's exact pipeline (it must reproduce its labels);
    // job 2 is a different seed (a genuinely different clustering)
    let job_tomls = [dir.join("job1.toml"), dir.join("job2.toml")];
    let pull_dirs = [dir.join("pull1"), dir.join("pull2")];
    for (i, seed) in [SEED, 13].into_iter().enumerate() {
        std::fs::write(
            &job_tomls[i],
            format!(
                "[pipeline]\ntotal_codes = 300\nk_clusters = 4\nseed = {seed}\n\n\
                 [bandwidth]\npolicy = \"median\"\nvalue = 0.5\n"
            ),
        )
        .context("write job config")?;
    }

    // both submits in flight at once: the runs interleave over the same
    // two site sessions
    let mut submits = Vec::new();
    for i in 0..2 {
        let child = Command::new(&bin)
            .arg("submit")
            .args(["--leader", &serve_addr])
            .args(["--config", job_tomls[i].to_str().unwrap()])
            .args(["--pull", pull_dirs[i].to_str().unwrap()])
            .stdout(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawn submit {i}"))?;
        submits.push(child);
    }
    for (i, child) in submits.into_iter().enumerate() {
        let out = child.wait_with_output().with_context(|| format!("wait for submit {i}"))?;
        let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
        print!("{stdout}");
        if !out.status.success() {
            bail!("submit {i} exited with {}", out.status);
        }
        // the run-scoped dialect: 2 frames up, 3 down, per site per run
        let reports = parse_netreports(&stdout)?;
        if reports.len() != SITES {
            bail!("submit {i}: expected {SITES} NETREPORT lines, got {}", reports.len());
        }
        for (site, c) in &reports {
            if c.up_frames != 2 || c.down_frames != 3 {
                bail!(
                    "submit {i} site {site}: expected 2 up / 3 down frames, got {} / {}",
                    c.up_frames,
                    c.down_frames
                );
            }
        }
    }
    leader_guard.wait()?;
    let rest = leader_rest.join().expect("leader stdout thread");
    if !rest.contains("SERVED_JOBS completed=2") {
        bail!("leader did not report 2 completed jobs:\n{rest}");
    }

    // job 1 (same spec as phase 1) must reproduce the reference labels —
    // pulled through the leader, not scraped from site files
    let mut job1_labels = vec![0u16; ds.len()];
    for (s, part) in parts.iter().enumerate() {
        let pulled = dsc::site::read_labels(&pull_dirs[0].join(format!("labels_site{s}.txt")))?;
        if pulled.len() != part.data.len() {
            bail!("job 1 site {s}: pulled {} labels for {} points", pulled.len(), part.data.len());
        }
        for (local, &g) in part.global_idx.iter().enumerate() {
            job1_labels[g as usize] = pulled[local];
        }
    }
    if job1_labels != base.labels {
        let diverged = job1_labels.iter().zip(&base.labels).filter(|(a, b)| a != b).count();
        bail!("job-server labels diverge from the channel run: {diverged}/{} differ", ds.len());
    }
    println!("job 1 labels (pulled via leader): identical to the in-process run ✓");

    // job 2 is a different seed: still an accurate clustering
    let mut job2_labels = vec![0u16; ds.len()];
    for (s, part) in parts.iter().enumerate() {
        let pulled = dsc::site::read_labels(&pull_dirs[1].join(format!("labels_site{s}.txt")))?;
        for (local, &g) in part.global_idx.iter().enumerate() {
            job2_labels[g as usize] = pulled[local];
        }
    }
    let acc2 = clustering_accuracy(&ds.labels, &job2_labels);
    println!("job 2 accuracy: {acc2:.4}");
    if acc2 < 0.9 {
        bail!("job 2 accuracy {acc2:.4} below the 0.9 floor");
    }
    drop(site_guards); // kill the persistent daemons

    // ── phase 3: ingest-then-resubmit — streaming shards over real TCP ──
    println!("\n=== ingest: sites restart with --ingest, a third submit labels every point ===");

    // an extra tranche of the same mixture, split across the sites like
    // the base set
    let extra_ds = dsc::data::gmm::paper_mixture_10d(600, 0.1, 99);
    let extra_parts = scenario::split(&extra_ds, Scenario::D3, SITES, SEED);
    let mut extra_csvs = Vec::new();
    for part in &extra_parts {
        let csv = dir.join(format!("extra{}.csv", part.site_id));
        csvio::save_dataset(&csv, &part.data, &["tcp_cluster example ingest tranche"])?;
        extra_csvs.push(csv);
    }
    // digests on: the SITEINFO2 frame rides the real TCP handshake here
    let site_toml = dir.join("site.toml");
    std::fs::write(&site_toml, "[site]\nreport_digest = true\n").context("write site config")?;

    let mut site_guards = Vec::new();
    let mut addrs = Vec::new();
    for s in 0..SITES {
        let mut child = Command::new(&bin)
            .arg("site")
            .args(["--listen", "127.0.0.1:0"])
            .args(["--data", csvs[s].to_str().unwrap()])
            .args(["--ingest", extra_csvs[s].to_str().unwrap()])
            .args(["--config", site_toml.to_str().unwrap()])
            .stdout(Stdio::piped())
            .spawn()
            .with_context(|| format!("spawn ingesting site {s}"))?;
        let stdout = child.stdout.take().expect("piped stdout");
        let mut reader = BufReader::new(stdout);
        // `--ingest` reports before LISTENING: check the fold landed
        let mut line = String::new();
        reader.read_line(&mut line).context("read ingest banner")?;
        let ingested = line
            .trim()
            .strip_prefix("INGESTED n_points=")
            .and_then(|rest| rest.split_whitespace().next())
            .and_then(|n| n.parse::<usize>().ok())
            .ok_or_else(|| anyhow!("site {s} printed {line:?}, expected INGESTED n_points=…"))?;
        if ingested != extra_parts[s].data.len() {
            bail!("site {s} ingested {ingested} points, expected {}", extra_parts[s].data.len());
        }
        line.clear();
        reader.read_line(&mut line).context("read site banner")?;
        let addr = line
            .trim()
            .strip_prefix("LISTENING ")
            .ok_or_else(|| anyhow!("site {s} printed {line:?}, expected LISTENING <addr>"))?
            .to_string();
        println!("site {s}: pid {} listening on {addr} (+{ingested} ingested points)", child.id());
        addrs.push(addr);
        std::thread::spawn(move || {
            let mut sink = String::new();
            while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
                sink.clear();
            }
        });
        site_guards.push(ChildGuard { child, name: "dsc site" });
    }

    let mut leader_child = Command::new(&bin)
        .arg("leader")
        .args(["--sites", &addrs.join(",")])
        .args(["--serve", "127.0.0.1:0"])
        .args(["--serve-limit", "1"])
        .args(["--config", server_toml.to_str().unwrap()])
        .stdout(Stdio::piped())
        .spawn()
        .context("spawn job-serving leader (ingest phase)")?;
    let leader_stdout = leader_child.stdout.take().expect("piped stdout");
    let mut leader_reader = BufReader::new(leader_stdout);
    let mut line = String::new();
    leader_reader.read_line(&mut line).context("read leader banner")?;
    let serve_addr = line
        .trim()
        .strip_prefix("SERVING ")
        .ok_or_else(|| anyhow!("leader printed {line:?}, expected SERVING <addr>"))?
        .to_string();
    println!("leader: pid {} serving jobs on {serve_addr}", leader_child.id());
    let leader_rest = std::thread::spawn(move || {
        let mut rest = String::new();
        let _ = leader_reader.read_to_string(&mut rest);
        rest
    });
    let mut leader_guard = ChildGuard { child: leader_child, name: "dsc leader --serve" };

    // same spec as job 1 — but the shards moved, so this is a fresh
    // clustering over 12_600 points, not a replay of the reference labels
    let pull3 = dir.join("pull3");
    let out = Command::new(&bin)
        .arg("submit")
        .args(["--leader", &serve_addr])
        .args(["--config", job_tomls[0].to_str().unwrap()])
        .args(["--pull", pull3.to_str().unwrap()])
        .output()
        .context("run submit over the ingested shards")?;
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    print!("{stdout}");
    if !out.status.success() {
        bail!("ingest-phase submit exited with {}", out.status);
    }
    // the digest frame is session-scoped: the run dialect stays 2 up / 3 down
    let reports = parse_netreports(&stdout)?;
    if reports.len() != SITES {
        bail!("ingest-phase submit: expected {SITES} NETREPORT lines, got {}", reports.len());
    }
    for (site, c) in &reports {
        if c.up_frames != 2 || c.down_frames != 3 {
            bail!(
                "ingest-phase submit site {site}: expected 2 up / 3 down frames, got {} / {}",
                c.up_frames,
                c.down_frames
            );
        }
    }
    leader_guard.wait()?;
    let rest = leader_rest.join().expect("leader stdout thread");
    if !rest.contains("SERVED_JOBS completed=1") {
        bail!("ingest-phase leader did not report 1 completed job:\n{rest}");
    }

    // every point — original shard plus ingested tranche — must come back
    // labelled, and the clustering must still be accurate on the combined
    // ground truth
    let mut truth = Vec::new();
    let mut pulled_all = Vec::new();
    for s in 0..SITES {
        let pulled = dsc::site::read_labels(&pull3.join(format!("labels_site{s}.txt")))?;
        let expect = parts[s].data.len() + extra_parts[s].data.len();
        if pulled.len() != expect {
            bail!(
                "ingest-phase site {s}: pulled {} labels for {expect} points ({} base + {} ingested)",
                pulled.len(),
                parts[s].data.len(),
                extra_parts[s].data.len()
            );
        }
        truth.extend_from_slice(&parts[s].data.labels);
        truth.extend_from_slice(&extra_parts[s].data.labels);
        pulled_all.extend_from_slice(&pulled);
    }
    if pulled_all.len() != ds.len() + extra_ds.len() {
        bail!(
            "ingest-phase pulled {} labels in total, expected {}",
            pulled_all.len(),
            ds.len() + extra_ds.len()
        );
    }
    let acc3 = clustering_accuracy(&truth, &pulled_all);
    println!(
        "ingest phase: {} labels pulled ({} base + {} ingested), accuracy {acc3:.4}",
        pulled_all.len(),
        ds.len(),
        extra_ds.len()
    );
    if acc3 < 0.9 {
        bail!("ingest-phase accuracy {acc3:.4} below the 0.9 floor");
    }
    drop(site_guards); // kill the ingesting daemons

    std::fs::remove_dir_all(&dir).ok();
    println!("\ntcp_cluster: all parity checks passed");
    Ok(())
}
