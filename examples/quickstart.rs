//! Quickstart: cluster distributed data in ~20 lines of public API.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use dsc::prelude::*;

fn main() -> anyhow::Result<()> {
    // 1. Data "arrives" at two distributed sites. Here we synthesize the
    //    paper's 10-D Gaussian mixture and split it D2-style (overlapping
    //    class supports across sites).
    let dataset = dsc::data::gmm::paper_mixture_10d(20_000, 0.3, 7);
    let parts = scenario::split(&dataset, Scenario::D2, 2, 7);

    // 2. Configure Algorithm 1: K-means DML at 40:1 compression, recursive
    //    normalized cuts on the collected codewords.
    let cfg = PipelineConfig {
        total_codes: 500,
        k_clusters: 4,
        ..Default::default()
    };

    // 3. Run: sites compress in parallel, the leader clusters the codeword
    //    union, labels populate back — only codewords cross the wire.
    let report = run_pipeline(&parts, &cfg)?;

    println!("accuracy   = {:.4}  (ARI {:.4}, NMI {:.4})", report.accuracy, report.ari, report.nmi);
    println!("codewords  = {}", report.n_codes);
    println!(
        "comm       = {} B vs {} B full data ({}x reduction)",
        report.net.total_bytes(),
        report.full_data_bytes,
        report.full_data_bytes / report.net.total_bytes().max(1)
    );
    println!(
        "elapsed    = {:.3}s  (max-site DML {:.3}s + central {:.3}s)",
        report.elapsed_model.as_secs_f64(),
        report.site_dml.iter().copied().max().unwrap_or_default().as_secs_f64(),
        report.central.as_secs_f64()
    );
    Ok(())
}
