//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf) — the quantities the
//! optimization pass tracks:
//!
//! * K-means assignment throughput (the per-site hot loop), in
//!   point·dims/µs;
//! * affinity-matrix build (the central O(n²d) kernel, native);
//! * Lanczos top-2 on the normalized affinity (recursive ncut's engine);
//! * dense vs sparse normalized mat-vec (`spmv`), including a 16k-codeword
//!   sparse run whose dense twin would need a 1 GiB matrix;
//! * XLA embed-artifact execution (the PJRT path incl. padding);
//! * end-to-end pipeline at the paper's 40:1 setting.
//!
//! Filter: `cargo bench --bench hotpath -- assign|affinity|spmv|lanczos|xla|pipeline`.
//! `DSC_THREADS` pins the pool for scaling curves.
//!
//! **Recorded trajectory mode** — `cargo bench --bench hotpath -- --json`:
//! runs the four SIMD-kernel arms (`assign`, `affinity`, `spmv`, `lanczos`)
//! twice each in one process — once forced to the scalar kernel arm
//! (`kernels::set_mode(Scalar)`) and once under runtime dispatch (`Auto`,
//! AVX2 where the CPU has it) — verifies the two outputs are **bit
//! identical** (any divergence fails the bench: the kernels promise parity
//! by construction, and the trajectory must never record a number produced
//! by a kernel that broke that promise), then writes
//! `BENCH_hotpath.json` (to `DSC_BENCH_OUT`, default `bench_out/`): per-arm
//! mean times, throughput in point·dims/µs, the dispatched/scalar speedup,
//! plus the detected CPU features and `DSC_THREADS` so the snapshot names
//! the hardware it was measured on. This is the compute-side twin of
//! `BENCH_jobserver.json` — the baseline ROADMAP item 4(b)'s XLA work has
//! to beat.

use std::time::Duration;

use anyhow::bail;
use dsc::bench::{time_it, Table};
use dsc::data::gmm;
use dsc::dml::{self, DmlKind, DmlParams};
use dsc::linalg::kernels::{self, SimdMode};
use dsc::prelude::*;
use dsc::rng::Rng;
use dsc::spectral::{affinity, njw, sparse};

fn want(filter: &Option<String>, key: &str) -> bool {
    filter.as_deref().map(|f| key.contains(f)).unwrap_or(true)
}

/// Tile an `n × src_dim` row-major point block to `n × d` by repeating
/// coordinates — the throughput arms sweep arbitrary dims over the same
/// 10-d mixture (geometry is irrelevant to a throughput number; shared
/// here so each new arm doesn't grow its own inline copy).
fn retile(src: &[f32], n: usize, src_dim: usize, d: usize) -> Vec<f32> {
    let mut pts = vec![0.0f32; n * d];
    for i in 0..n {
        for j in 0..d {
            pts[i * d + j] = src[i * src_dim + (j % src_dim)];
        }
    }
    pts
}

/// One SIMD-trajectory arm: timings and throughput for the scalar and
/// dispatched kernel arms over the identical workload, with the bitwise
/// output fingerprints already verified equal.
struct ArmRecord {
    name: &'static str,
    config: String,
    /// point·dims per run — the unit the throughput is quoted in.
    ops: f64,
    scalar_ms: f64,
    dispatched_ms: f64,
}

impl ArmRecord {
    fn speedup(&self) -> f64 {
        self.scalar_ms / self.dispatched_ms.max(1e-12)
    }
    /// point·dims/µs at the given mean milliseconds.
    fn throughput(&self, ms: f64) -> f64 {
        self.ops / (ms.max(1e-12) * 1e3)
    }
    fn to_json(&self) -> String {
        format!(
            "{{\n    \"config\": \"{}\",\n    \"point_dims_per_run\": {:.0},\n    \
             \"scalar_ms\": {:.3},\n    \"dispatched_ms\": {:.3},\n    \
             \"throughput_scalar_pd_per_us\": {:.2},\n    \
             \"throughput_dispatched_pd_per_us\": {:.2},\n    \
             \"speedup\": {:.3},\n    \"parity\": \"bit-identical\"\n  }}",
            self.config,
            self.ops,
            self.scalar_ms,
            self.dispatched_ms,
            self.throughput(self.scalar_ms),
            self.throughput(self.dispatched_ms),
            self.speedup(),
        )
    }
}

/// Time `f` under the scalar arm, then under runtime dispatch, in this
/// process; bail if their bitwise output fingerprints differ. `f` must be
/// deterministic given the kernel arm (every arm below is).
fn time_both_arms<T: PartialEq>(
    name: &'static str,
    config: String,
    ops: f64,
    warmup: usize,
    samples: usize,
    mut f: impl FnMut() -> T,
) -> anyhow::Result<ArmRecord> {
    kernels::set_mode(SimdMode::Scalar);
    let mut out_scalar = None;
    let s_stats = time_it(warmup, samples, || out_scalar = Some(f()));
    kernels::set_mode(SimdMode::Auto);
    let mut out_auto = None;
    let a_stats = time_it(warmup, samples, || out_auto = Some(f()));
    kernels::set_mode(SimdMode::Auto);
    if out_scalar != out_auto {
        bail!(
            "{name}: scalar and dispatched kernel arms produced different bits — \
             parity violated, refusing to record a trajectory"
        );
    }
    Ok(ArmRecord {
        name,
        config,
        ops,
        scalar_ms: s_stats.mean_secs() * 1e3,
        dispatched_ms: a_stats.mean_secs() * 1e3,
    })
}

/// The recorded-trajectory mode: four arms, scalar vs dispatched, bitwise
/// parity enforced, JSON written for CI to upload.
fn json_mode() -> anyhow::Result<()> {
    let mut arms: Vec<ArmRecord> = Vec::new();

    // assign — one Lloyd sweep over retiled 16-d points, the per-site hot
    // loop (kernels: axpy_f32 for the score sweep, sqdist_f32 in seeding).
    {
        let (n, k, d) = (20_000usize, 256usize, 16usize);
        let base = gmm::paper_mixture_10d(n, 0.3, 3);
        let mut ds = base;
        ds.points = retile(&ds.points, n, 10, d);
        ds.dim = d;
        let params =
            DmlParams { kind: DmlKind::KMeans, target_codes: k, max_iters: 1, tol: 0.0, seed: 1 };
        arms.push(time_both_arms(
            "assign",
            format!("n={n} k={k} d={d} sweeps=1"),
            (n * k * d) as f64,
            1,
            3,
            || {
                let cb = dml::apply(&ds, &params);
                let cw_bits: Vec<u32> = cb.codewords.iter().map(|v| v.to_bits()).collect();
                (cb.assign, cw_bits)
            },
        )?);
    }

    // affinity — the central O(n²d) build (kernel: dot_f32 inside the
    // expanded-form distance).
    {
        let (n, d) = (1_500usize, 16usize);
        let base = gmm::paper_mixture_10d(n, 0.3, 5);
        let pts = retile(&base.points, n, 10, d);
        let w = vec![1.0f32; n];
        arms.push(time_both_arms(
            "affinity",
            format!("n={n} d={d}"),
            (n * n * d) as f64,
            1,
            3,
            || {
                let a = affinity::build(&pts, d, &w, 1.5);
                a.data.iter().map(|v| v.to_bits()).collect::<Vec<u32>>()
            },
        )?);
    }

    // spmv — dense normalized mat-vec (kernel: dot_f32_f64) and the CSR
    // gather twin (kernel: spmv_row_f64), Lanczos' entire inner loop.
    {
        let m = 2_000usize;
        let ds = gmm::paper_mixture_10d(m, 0.3, 17);
        let w = vec![1.0f32; m];
        let dense = affinity::build(&ds.points, 10, &w, 1.5);
        let x: Vec<f64> =
            (0..m).map(|i| ((i.wrapping_mul(2_654_435_761)) % 1000) as f64 / 1000.0).collect();
        arms.push(time_both_arms(
            "spmv_dense",
            format!("m={m}"),
            (m * m) as f64,
            2,
            9,
            || {
                let mut y = vec![0.0f64; m];
                dense.normalized_matvec(&x, &mut y);
                y.iter().map(|v| v.to_bits()).collect::<Vec<u64>>()
            },
        )?);

        let ms = 8_000usize;
        let dss = gmm::paper_mixture_10d(ms, 0.3, 23);
        let ws = vec![1.0f32; ms];
        let mut grng = Rng::new(29);
        let sp = sparse::build_knn(&dss.points, 10, &ws, 1.5, 32, &mut grng);
        let nnz = sp.nnz();
        let xs: Vec<f64> =
            (0..ms).map(|i| ((i.wrapping_mul(2_654_435_761)) % 1000) as f64 / 1000.0).collect();
        arms.push(time_both_arms(
            "spmv_sparse",
            format!("m={ms} k=32 nnz={nnz}"),
            nnz as f64,
            2,
            9,
            || {
                let mut y = vec![0.0f64; ms];
                sp.normalized_matvec(&xs, &mut y);
                y.iter().map(|v| v.to_bits()).collect::<Vec<u64>>()
            },
        )?);
    }

    // lanczos — top-2 eigenvalues through NormalizedOp; end-to-end
    // deterministic because only the kernels touch the data between the
    // f64-serial Lanczos recurrences. ops: one matvec is m² point·dims and
    // the iteration count varies, so throughput is quoted per-matvec-size
    // and the speedup is the meaningful number.
    {
        let n = 1_500usize;
        let ds = gmm::paper_mixture_10d(n, 0.3, 7);
        let w = vec![1.0f32; n];
        let aff = affinity::build(&ds.points, 10, &w, 2.0);
        arms.push(time_both_arms(
            "lanczos",
            format!("n={n} top=2"),
            (n * n) as f64,
            1,
            3,
            || {
                let mut rng = Rng::new(9);
                let evals = njw::top_eigenvalues(&aff, 2, &mut rng);
                evals.iter().map(|v| v.to_bits()).collect::<Vec<u64>>()
            },
        )?);
    }

    let features = kernels::detected_features();
    let threads = dsc::par::threads();
    kernels::set_mode(SimdMode::Auto);
    let dispatched = kernels::active_arm();

    let mut table = Table::new(
        format!("SIMD kernel trajectory ({threads} threads, dispatch={dispatched})"),
        &["arm", "config", "scalar ms", "dispatched ms", "speedup"],
    );
    for a in &arms {
        table.row(&[
            a.name.into(),
            a.config.clone(),
            format!("{:.3}", a.scalar_ms),
            format!("{:.3}", a.dispatched_ms),
            format!("{:.3}x", a.speedup()),
        ]);
    }
    print!("{}", table.render());

    let out_dir = std::env::var("DSC_BENCH_OUT").unwrap_or_else(|_| "bench_out".into());
    std::fs::create_dir_all(&out_dir)?;
    let path = std::path::Path::new(&out_dir).join("BENCH_hotpath.json");
    let arm_objs: Vec<String> =
        arms.iter().map(|a| format!("  \"{}\": {}", a.name, a.to_json())).collect();
    let body = format!(
        "{{\n  \"bench\": \"hotpath\",\n  \"executed\": true,\n  \
         \"threads\": {threads},\n  \"cpu_features\": \"{features}\",\n  \
         \"dispatched_arm\": \"{dispatched}\",\n  \
         \"throughput_unit\": \"point*dims/us\",\n{}\n}}\n",
        arm_objs.join(",\n"),
    );
    std::fs::write(&path, body)?;
    println!("\nwrote {}", path.display());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--json") {
        return json_mode();
    }
    let filter = args.into_iter().find(|a| !a.starts_with('-'));
    let mut table = Table::new(
        format!(
            "Hot paths ({} threads, simd={})",
            dsc::par::threads(),
            kernels::active_arm()
        ),
        &["bench", "config", "mean", "throughput"],
    );

    if want(&filter, "assign") {
        for (n, k, d) in [(40_000usize, 338usize, 42usize), (40_000, 1000, 10), (100_000, 500, 28)]
        {
            let mut ds = gmm::paper_mixture_10d(n, 0.3, 3);
            // reshape to arbitrary d by tiling (throughput test only)
            if d != 10 {
                ds.points = retile(&ds.points, n, 10, d);
                ds.dim = d;
            }
            let params =
                DmlParams { kind: DmlKind::KMeans, target_codes: k, max_iters: 1, tol: 0.0, seed: 1 };
            let stats = time_it(1, 5, || {
                let _ = dml::apply(&ds, &params);
            });
            // one sweep ≈ n·k·d mul-adds (plus seeding, amortized)
            let ops = (n as f64) * (k as f64) * (d as f64);
            table.row(&[
                "kmeans_assign_sweep".into(),
                format!("n={n} k={k} d={d}"),
                format!("{stats}"),
                format!("{:.1} Mops/ms", ops / stats.mean_secs() / 1e9),
            ]);
        }
    }

    if want(&filter, "affinity") {
        for (n, d) in [(500usize, 10usize), (1000, 10), (2000, 28)] {
            let ds = gmm::paper_mixture_10d(n, 0.3, 5);
            let pts = retile(&ds.points, n, 10, d);
            let w = vec![1.0f32; n];
            let stats = time_it(1, 7, || {
                let _ = affinity::build(&pts, d, &w, 1.5);
            });
            let cells = (n as f64) * (n as f64);
            table.row(&[
                "affinity_build".into(),
                format!("n={n} d={d}"),
                format!("{stats}"),
                format!("{:.1} Mcell/s", cells / stats.mean_secs() / 1e6),
            ]);
        }
    }

    if want(&filter, "spmv") {
        // Head-to-head at sizes the dense path can still hold…
        for (m, knn) in [(2_000usize, 32usize), (4_000, 32)] {
            let ds = gmm::paper_mixture_10d(m, 0.3, 17);
            let w = vec![1.0f32; m];
            let dense = affinity::build(&ds.points, 10, &w, 1.5);
            let mut grng = Rng::new(19);
            let sp = sparse::build_knn(&ds.points, 10, &w, 1.5, knn, &mut grng);
            let x: Vec<f64> =
                (0..m).map(|i| ((i.wrapping_mul(2_654_435_761)) % 1000) as f64 / 1000.0).collect();
            let mut y = vec![0.0f64; m];

            let dstats = time_it(2, 15, || dense.normalized_matvec(&x, &mut y));
            table.row(&[
                "spmv_dense".into(),
                format!("m={m}"),
                format!("{dstats}"),
                format!("{:.1} MB matrix", (m * m * 4) as f64 / 1e6),
            ]);
            let sstats = time_it(2, 15, || sp.normalized_matvec(&x, &mut y));
            table.row(&[
                "spmv_sparse".into(),
                format!("m={m} k={knn} nnz={}", sp.nnz()),
                format!("{sstats}"),
                format!("{:.1} MB CSR", sp.storage_bytes() as f64 / 1e6),
            ]);
        }
        // …and the 16k-codeword regime where the dense matrix alone would
        // be 16384² × 4 B = 1 GiB and is not allocated at all.
        let m = 16_384usize;
        let ds = gmm::paper_mixture_10d(m, 0.3, 23);
        let w = vec![1.0f32; m];
        let mut grng = Rng::new(29);
        let sp = sparse::build_knn(&ds.points, 10, &w, 1.5, 32, &mut grng);
        let x: Vec<f64> =
            (0..m).map(|i| ((i.wrapping_mul(2_654_435_761)) % 1000) as f64 / 1000.0).collect();
        let mut y = vec![0.0f64; m];
        let sstats = time_it(2, 15, || sp.normalized_matvec(&x, &mut y));
        table.row(&[
            "spmv_sparse".into(),
            format!("m={m} k=32 nnz={}", sp.nnz()),
            format!("{sstats}"),
            format!(
                "{:.1} MB CSR vs {:.0} MB dense (not allocated)",
                sp.storage_bytes() as f64 / 1e6,
                (m * m * 4) as f64 / 1e6
            ),
        ]);
    }

    if want(&filter, "lanczos") {
        for n in [500usize, 1000, 2000] {
            let ds = gmm::paper_mixture_10d(n, 0.3, 7);
            let w = vec![1.0f32; n];
            let aff = affinity::build(&ds.points, 10, &w, 2.0);
            let stats = time_it(1, 5, || {
                let mut rng = Rng::new(9);
                let _ = njw::top_eigenvalues(&aff, 2, &mut rng);
            });
            table.row(&[
                "lanczos_top2".into(),
                format!("n={n}"),
                format!("{stats}"),
                String::new(),
            ]);
        }
    }

    if want(&filter, "xla") {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            let rt = dsc::runtime::XlaRuntime::new("artifacts")?;
            for n in [256usize, 1024, 2048] {
                let ds = gmm::paper_mixture_10d(n, 0.3, 11);
                let w = vec![1.0f32; n];
                // warm the executable cache before timing execution
                let _ = rt.embed(&ds.points, 10, &w, 1.5)?;
                let stats = time_it(1, 5, || {
                    let _ = rt.embed(&ds.points, 10, &w, 1.5).unwrap();
                });
                table.row(&[
                    "xla_embed_exec".into(),
                    format!("n={n} d=10→16"),
                    format!("{stats}"),
                    String::new(),
                ]);
            }
        } else {
            eprintln!("xla bench skipped: artifacts missing");
        }
    }

    if want(&filter, "pipeline") {
        let n: usize =
            std::env::var("DSC_N").ok().and_then(|v| v.parse().ok()).unwrap_or(40_000);
        let ds = gmm::paper_mixture_10d(n, 0.3, 13);
        let parts = scenario::split(&ds, Scenario::D3, 2, 13);
        let cfg = PipelineConfig {
            total_codes: n / 40,
            k_clusters: 4,
            bandwidth: Bandwidth::MedianScale(0.5),
            seed: 15,
            ..Default::default()
        };
        let mut phase = (Duration::ZERO, Duration::ZERO, 0usize);
        let stats = time_it(0, 3, || {
            let r = run_pipeline(&parts, &cfg).unwrap();
            phase = (
                r.site_dml.iter().copied().max().unwrap_or_default(),
                r.central,
                r.n_codes,
            );
        });
        table.row(&[
            "pipeline_e2e".into(),
            format!("n={n} codes={} sites=2", phase.2),
            format!("{stats}"),
            format!(
                "dml {:.2}s + central {:.2}s",
                phase.0.as_secs_f64(),
                phase.1.as_secs_f64()
            ),
        ]);
    }

    print!("{}", table.render());
    table.save_csv("hotpath")?;
    Ok(())
}
