//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf) — the quantities the
//! optimization pass tracks:
//!
//! * K-means assignment throughput (the per-site hot loop), in
//!   point·dims/µs;
//! * affinity-matrix build (the central O(n²d) kernel, native);
//! * Lanczos top-2 on the normalized affinity (recursive ncut's engine);
//! * dense vs sparse normalized mat-vec (`spmv`), including a 16k-codeword
//!   sparse run whose dense twin would need a 1 GiB matrix;
//! * XLA embed-artifact execution (the PJRT path incl. padding);
//! * end-to-end pipeline at the paper's 40:1 setting.
//!
//! Filter: `cargo bench --bench hotpath -- assign|affinity|spmv|lanczos|xla|pipeline`.
//! `DSC_THREADS` pins the pool for scaling curves.

use std::time::Duration;

use dsc::bench::{time_it, Table};
use dsc::data::gmm;
use dsc::dml::{self, DmlKind, DmlParams};
use dsc::prelude::*;
use dsc::rng::Rng;
use dsc::spectral::{affinity, njw, sparse};

fn want(filter: &Option<String>, key: &str) -> bool {
    filter.as_deref().map(|f| key.contains(f)).unwrap_or(true)
}

fn main() -> anyhow::Result<()> {
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let mut table = Table::new(
        format!("Hot paths ({} threads)", dsc::par::threads()),
        &["bench", "config", "mean", "throughput"],
    );

    if want(&filter, "assign") {
        for (n, k, d) in [(40_000usize, 338usize, 42usize), (40_000, 1000, 10), (100_000, 500, 28)]
        {
            let ds = gmm::paper_mixture_10d(n, 0.3, 3);
            let mut ds = ds;
            // reshape to arbitrary d by tiling (throughput test only)
            if d != 10 {
                let mut pts = vec![0.0f32; n * d];
                for i in 0..n {
                    for j in 0..d {
                        pts[i * d + j] = ds.points[i * 10 + (j % 10)];
                    }
                }
                ds.points = pts;
                ds.dim = d;
            }
            let params =
                DmlParams { kind: DmlKind::KMeans, target_codes: k, max_iters: 1, tol: 0.0, seed: 1 };
            let stats = time_it(1, 5, || {
                let _ = dml::apply(&ds, &params);
            });
            // one sweep ≈ n·k·d mul-adds (plus seeding, amortized)
            let ops = (n as f64) * (k as f64) * (d as f64);
            table.row(&[
                "kmeans_assign_sweep".into(),
                format!("n={n} k={k} d={d}"),
                format!("{stats}"),
                format!("{:.1} Mops/ms", ops / stats.mean_secs() / 1e9),
            ]);
        }
    }

    if want(&filter, "affinity") {
        for (n, d) in [(500usize, 10usize), (1000, 10), (2000, 28)] {
            let ds = gmm::paper_mixture_10d(n, 0.3, 5);
            let pts = if d == 10 {
                ds.points.clone()
            } else {
                let mut p = vec![0.0f32; n * d];
                for i in 0..n {
                    for j in 0..d {
                        p[i * d + j] = ds.points[i * 10 + (j % 10)];
                    }
                }
                p
            };
            let w = vec![1.0f32; n];
            let stats = time_it(1, 7, || {
                let _ = affinity::build(&pts, d, &w, 1.5);
            });
            let cells = (n as f64) * (n as f64);
            table.row(&[
                "affinity_build".into(),
                format!("n={n} d={d}"),
                format!("{stats}"),
                format!("{:.1} Mcell/s", cells / stats.mean_secs() / 1e6),
            ]);
        }
    }

    if want(&filter, "spmv") {
        // Head-to-head at sizes the dense path can still hold…
        for (m, knn) in [(2_000usize, 32usize), (4_000, 32)] {
            let ds = gmm::paper_mixture_10d(m, 0.3, 17);
            let w = vec![1.0f32; m];
            let dense = affinity::build(&ds.points, 10, &w, 1.5);
            let mut grng = Rng::new(19);
            let sp = sparse::build_knn(&ds.points, 10, &w, 1.5, knn, &mut grng);
            let x: Vec<f64> =
                (0..m).map(|i| ((i.wrapping_mul(2_654_435_761)) % 1000) as f64 / 1000.0).collect();
            let mut y = vec![0.0f64; m];

            let dstats = time_it(2, 15, || dense.normalized_matvec(&x, &mut y));
            table.row(&[
                "spmv_dense".into(),
                format!("m={m}"),
                format!("{dstats}"),
                format!("{:.1} MB matrix", (m * m * 4) as f64 / 1e6),
            ]);
            let sstats = time_it(2, 15, || sp.normalized_matvec(&x, &mut y));
            table.row(&[
                "spmv_sparse".into(),
                format!("m={m} k={knn} nnz={}", sp.nnz()),
                format!("{sstats}"),
                format!("{:.1} MB CSR", sp.storage_bytes() as f64 / 1e6),
            ]);
        }
        // …and the 16k-codeword regime where the dense matrix alone would
        // be 16384² × 4 B = 1 GiB and is not allocated at all.
        let m = 16_384usize;
        let ds = gmm::paper_mixture_10d(m, 0.3, 23);
        let w = vec![1.0f32; m];
        let mut grng = Rng::new(29);
        let sp = sparse::build_knn(&ds.points, 10, &w, 1.5, 32, &mut grng);
        let x: Vec<f64> =
            (0..m).map(|i| ((i.wrapping_mul(2_654_435_761)) % 1000) as f64 / 1000.0).collect();
        let mut y = vec![0.0f64; m];
        let sstats = time_it(2, 15, || sp.normalized_matvec(&x, &mut y));
        table.row(&[
            "spmv_sparse".into(),
            format!("m={m} k=32 nnz={}", sp.nnz()),
            format!("{sstats}"),
            format!(
                "{:.1} MB CSR vs {:.0} MB dense (not allocated)",
                sp.storage_bytes() as f64 / 1e6,
                (m * m * 4) as f64 / 1e6
            ),
        ]);
    }

    if want(&filter, "lanczos") {
        for n in [500usize, 1000, 2000] {
            let ds = gmm::paper_mixture_10d(n, 0.3, 7);
            let w = vec![1.0f32; n];
            let aff = affinity::build(&ds.points, 10, &w, 2.0);
            let stats = time_it(1, 5, || {
                let mut rng = Rng::new(9);
                let _ = njw::top_eigenvalues(&aff, 2, &mut rng);
            });
            table.row(&[
                "lanczos_top2".into(),
                format!("n={n}"),
                format!("{stats}"),
                String::new(),
            ]);
        }
    }

    if want(&filter, "xla") {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            let rt = dsc::runtime::XlaRuntime::new("artifacts")?;
            for n in [256usize, 1024, 2048] {
                let ds = gmm::paper_mixture_10d(n, 0.3, 11);
                let w = vec![1.0f32; n];
                // warm the executable cache before timing execution
                let _ = rt.embed(&ds.points, 10, &w, 1.5)?;
                let stats = time_it(1, 5, || {
                    let _ = rt.embed(&ds.points, 10, &w, 1.5).unwrap();
                });
                table.row(&[
                    "xla_embed_exec".into(),
                    format!("n={n} d=10→16"),
                    format!("{stats}"),
                    String::new(),
                ]);
            }
        } else {
            eprintln!("xla bench skipped: artifacts missing");
        }
    }

    if want(&filter, "pipeline") {
        let n: usize =
            std::env::var("DSC_N").ok().and_then(|v| v.parse().ok()).unwrap_or(40_000);
        let ds = gmm::paper_mixture_10d(n, 0.3, 13);
        let parts = scenario::split(&ds, Scenario::D3, 2, 13);
        let cfg = PipelineConfig {
            total_codes: n / 40,
            k_clusters: 4,
            bandwidth: Bandwidth::MedianScale(0.5),
            seed: 15,
            ..Default::default()
        };
        let mut phase = (Duration::ZERO, Duration::ZERO, 0usize);
        let stats = time_it(0, 3, || {
            let r = run_pipeline(&parts, &cfg).unwrap();
            phase = (
                r.site_dml.iter().copied().max().unwrap_or_default(),
                r.central,
                r.n_codes,
            );
        });
        table.row(&[
            "pipeline_e2e".into(),
            format!("n={n} codes={} sites=2", phase.2),
            format!("{stats}"),
            format!(
                "dml {:.2}s + central {:.2}s",
                phase.0.as_secs_f64(),
                phase.1.as_secs_f64()
            ),
        ]);
    }

    print!("{}", table.render());
    table.save_csv("hotpath")?;
    Ok(())
}
