//! Ablations A1–A5 (DESIGN.md §3) — the design choices behind the paper's
//! framework, each isolated:
//!
//! * **A1 compression** — accuracy vs codebook size k (Theorem 3 predicts
//!   the extra error decays like k^{-2/d});
//! * **A2 weighting** — group-size-weighted vs unweighted affinity;
//! * **A3 comm** — bytes on the wire vs accuracy across compression, with
//!   the modeled WAN transfer time;
//! * **A4 backend** — native Lanczos vs XLA artifact embedding (accuracy
//!   parity + central-step latency);
//! * **A5 algo** — recursive ncut vs NJW embedding clustering.
//!
//! Filter: `cargo bench --bench ablations -- compression|weighting|comm|backend|algo`.

use dsc::bench::Table;
use dsc::data::gmm;
use dsc::prelude::*;

fn want(filter: &Option<String>, key: &str) -> bool {
    filter.as_deref().map(|f| key.contains(f)).unwrap_or(true)
}

fn mk_cfg(codes: usize) -> PipelineConfig {
    PipelineConfig {
        total_codes: codes,
        k_clusters: 4,
        bandwidth: Bandwidth::MedianScale(0.5),
        seed: 61,
        ..Default::default()
    }
}

fn main() -> anyhow::Result<()> {
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let n: usize = std::env::var("DSC_N").ok().and_then(|v| v.parse().ok()).unwrap_or(20_000);
    let ds = gmm::paper_mixture_10d(n, 0.3, 67);
    let parts = scenario::split(&ds, Scenario::D2, 2, 67);

    if want(&filter, "compression") {
        let mut t = Table::new(
            "A1 — accuracy vs codebook size (Theorem 3: error ~ k^{-2/d})",
            &["codewords", "compression", "accuracy", "distortion_site0", "wire_bytes"],
        );
        for codes in [50usize, 100, 200, 400, 800, 1600] {
            let r = run_pipeline(&parts, &mk_cfg(codes))?;
            t.row(&[
                codes.to_string(),
                format!("{}:1", n / codes),
                format!("{:.4}", r.accuracy),
                format!("{:.4}", r.site_distortion[0]),
                r.net.total_bytes().to_string(),
            ]);
        }
        print!("{}", t.render());
        t.save_csv("ablation_compression")?;
    }

    if want(&filter, "weighting") {
        let mut t = Table::new(
            "A2 — weighted vs unweighted codeword affinity",
            &["codewords", "unweighted acc", "weighted acc"],
        );
        for codes in [100usize, 400, 1000] {
            let r_u = run_pipeline(&parts, &mk_cfg(codes))?;
            let mut cfg_w = mk_cfg(codes);
            cfg_w.weighted_affinity = true;
            let r_w = run_pipeline(&parts, &cfg_w)?;
            t.row(&[
                codes.to_string(),
                format!("{:.4}", r_u.accuracy),
                format!("{:.4}", r_w.accuracy),
            ]);
        }
        print!("{}", t.render());
        t.save_csv("ablation_weighting")?;
    }

    if want(&filter, "comm") {
        let mut t = Table::new(
            "A3 — communication vs accuracy (link: 100 Mbit/s, 20 ms)",
            &[
                "codewords",
                "wire_bytes",
                "proto_bytes",
                "full_data_bytes",
                "reduction",
                "transfer_ms",
                "accuracy",
            ],
        );
        for codes in [50usize, 200, 800, 3200.min(n / 8)] {
            let r = run_pipeline(&parts, &mk_cfg(codes))?;
            // Everything on the wire beyond the raw codeword payload
            // (f32 coords + u32 weight per codeword): frame headers, the
            // registration/work-order control frames, and the label
            // frames coming back. Identical across the channel and TCP
            // transports (docs/PROTOCOL.md, "Byte accounting").
            let payload = r.n_codes as u64 * (ds.dim as u64 * 4 + 4);
            t.row(&[
                codes.to_string(),
                r.net.total_bytes().to_string(),
                r.net.total_bytes().saturating_sub(payload).to_string(),
                r.full_data_bytes.to_string(),
                format!("{}x", r.full_data_bytes / r.net.total_bytes().max(1)),
                format!("{:.1}", r.net.max_link_time().as_secs_f64() * 1e3),
                format!("{:.4}", r.accuracy),
            ]);
        }
        print!("{}", t.render());
        t.save_csv("ablation_comm")?;
    }

    if want(&filter, "backend") {
        let has_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
        if !has_artifacts {
            eprintln!("A4 skipped: artifacts missing (run `make artifacts`)");
        } else {
            let mut t = Table::new(
                "A4 — central-step backend: native Lanczos vs XLA artifact",
                &["backend", "accuracy", "central_s", "total_s"],
            );
            for backend in [Backend::Native, Backend::Xla, Backend::XlaFull] {
                let mut cfg = mk_cfg(400);
                cfg.backend = backend;
                cfg.algo = Algo::Njw; // compare like against like
                let r = run_pipeline(&parts, &cfg)?;
                t.row(&[
                    format!("{backend:?}"),
                    format!("{:.4}", r.accuracy),
                    format!("{:.3}", r.central.as_secs_f64()),
                    format!("{:.3}", r.elapsed_model.as_secs_f64()),
                ]);
            }
            print!("{}", t.render());
            t.save_csv("ablation_backend")?;
        }
    }

    if want(&filter, "baseline") {
        let mut t = Table::new(
            "A6 — DML codewords vs random-landmark baseline (equal budget)",
            &["dml", "codewords", "accuracy", "distortion_site0", "max_dml_s"],
        );
        for kind in [
            dsc::dml::DmlKind::KMeans,
            dsc::dml::DmlKind::RpTree,
            dsc::dml::DmlKind::RandomSample,
        ] {
            let mut cfg = mk_cfg(400);
            cfg.dml = kind;
            let r = run_pipeline(&parts, &cfg)?;
            t.row(&[
                kind.to_string(),
                r.n_codes.to_string(),
                format!("{:.4}", r.accuracy),
                format!("{:.4}", r.site_distortion[0]),
                format!(
                    "{:.3}",
                    r.site_dml.iter().copied().max().unwrap_or_default().as_secs_f64()
                ),
            ]);
        }
        print!("{}", t.render());
        t.save_csv("ablation_baseline")?;
    }

    if want(&filter, "algo") {
        let mut t = Table::new(
            "A5 — recursive normalized cuts vs NJW embedding",
            &["algo", "codewords", "accuracy", "central_s"],
        );
        for codes in [200usize, 800] {
            for algo in [Algo::RecursiveNcut, Algo::Njw] {
                let mut cfg = mk_cfg(codes);
                cfg.algo = algo;
                let r = run_pipeline(&parts, &cfg)?;
                t.row(&[
                    format!("{algo:?}"),
                    codes.to_string(),
                    format!("{:.4}", r.accuracy),
                    format!("{:.3}", r.central.as_secs_f64()),
                ]);
            }
        }
        print!("{}", t.render());
        t.save_csv("ablation_algo")?;
    }
    Ok(())
}
