//! Regenerates Tables 3 and 4: accuracy + elapsed time for the eight UC
//! Irvine datasets (proxies — DESIGN.md §5) under non-distributed vs
//! D1/D2/D3 with two sites.
//!
//! * `cargo bench --bench table3_table4_uci -- kmeans`   → Table 3
//! * `cargo bench --bench table3_table4_uci -- rptrees`  → Table 4
//! * `cargo bench --bench table3_table4_uci -- summary`  → Tables 1–2
//!
//! `DSC_N` caps the per-dataset point count (default: each spec's scaled
//! `default_n`; the paper's full sizes via `DSC_FULL_SCALE=1`).
//!
//! Expected shape vs the paper: per-row distributed accuracy within noise
//! of non-distributed; elapsed time of distributed runs roughly half the
//! non-distributed row (two sites working in parallel); Table-4 (rpTrees)
//! times several× lower than Table-3 at slightly lower accuracy.

use dsc::bench::Table;
use dsc::data::uci_proxy;
use dsc::dml::DmlKind;
use dsc::prelude::*;

fn main() -> anyhow::Result<()> {
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let which = filter.as_deref().unwrap_or("all");

    if which == "summary" || which == "all" {
        summary();
    }
    if ["kmeans", "table3", "all"].contains(&which) {
        run_table(DmlKind::KMeans, "table3")?;
    }
    if ["rptrees", "table4", "all"].contains(&which) {
        run_table(DmlKind::RpTree, "table4")?;
    }
    Ok(())
}

/// Tables 1 + 2: dataset inventory and site configurations.
fn summary() {
    let mut t1 = Table::new(
        "Table 1 — UCI dataset proxies",
        &["dataset", "features", "paper_n", "bench_n", "classes", "ratio", "codewords"],
    );
    for s in uci_proxy::specs() {
        t1.row(&[
            s.name.to_string(),
            s.dim.to_string(),
            s.paper_n.to_string(),
            bench_n(s).to_string(),
            s.n_classes.to_string(),
            s.paper_ratio.to_string(),
            s.target_codewords().to_string(),
        ]);
    }
    print!("{}", t1.render());

    let mut t2 = Table::new(
        "Table 2 — site-fraction matrices (share of each class per site)",
        &["classes", "scenario", "site fractions [site][class]"],
    );
    for classes in [2usize, 3, 5] {
        for sc in [Scenario::D1, Scenario::D2, Scenario::D3] {
            let f = dsc::data::scenario::fractions(sc, 2, classes);
            t2.row(&[classes.to_string(), sc.to_string(), format!("{f:?}")]);
        }
    }
    print!("{}", t2.render());
}

fn bench_n(spec: &uci_proxy::UciSpec) -> usize {
    if std::env::var("DSC_FULL_SCALE").is_ok() {
        return spec.paper_n;
    }
    let cap: usize =
        std::env::var("DSC_N").ok().and_then(|v| v.parse().ok()).unwrap_or(usize::MAX);
    spec.default_n().min(cap)
}

fn run_table(dml: DmlKind, name: &str) -> anyhow::Result<()> {
    let mut table = Table::new(
        format!(
            "{} — UCI proxies, {dml} DML, 2 sites (paper acc in parens)",
            if dml == DmlKind::KMeans { "Table 3" } else { "Table 4" }
        ),
        &["dataset", "non-dist acc", "non-dist s", "D1 acc", "D1 s", "D2 acc", "D2 s", "D3 acc", "D3 s"],
    );

    for spec in uci_proxy::specs() {
        let n = bench_n(spec);
        let ds = spec.generate(n, 41);
        let cfg = PipelineConfig {
            dml,
            total_codes: spec.target_codewords().min(n / 4).max(16),
            k_clusters: spec.n_classes,
            bandwidth: Bandwidth::MedianScale(0.75),
            seed: 43,
            ..Default::default()
        };

        let base = run_pipeline(
            &[SitePart {
                site_id: 0,
                data: ds.clone(),
                global_idx: (0..ds.len() as u32).collect(),
            }],
            &cfg,
        )?;
        let paper_acc = match dml {
            DmlKind::KMeans => spec.paper_acc_kmeans,
            DmlKind::RpTree => spec.paper_acc_rptrees,
        };
        let mut cells = vec![
            format!("{} (paper {:.3})", spec.name, paper_acc),
            format!("{:.4}", base.accuracy),
            format!("{:.2}", base.elapsed_model.as_secs_f64()),
        ];
        for sc in [Scenario::D1, Scenario::D2, Scenario::D3] {
            let parts = scenario::split(&ds, sc, 2, 47);
            let r = run_pipeline(&parts, &cfg)?;
            cells.push(format!("{:.4}", r.accuracy));
            cells.push(format!("{:.2}", r.elapsed_model.as_secs_f64()));
        }
        table.row(&cells);
        eprintln!("  done {}", spec.name);
    }
    print!("{}", table.render());
    table.save_csv(name)?;
    Ok(())
}
