//! Regenerates the paper's synthetic-data evaluation:
//!
//! * **Fig. 5** — the 2-D 4-component mixture scatter with representative
//!   points from 2 sites (emitted as CSVs for plotting);
//! * **Fig. 6** — clustering accuracy on the 10-D mixture, ρ ∈
//!   {0.1, 0.3, 0.6}, scenarios D1/D2/D3 vs non-distributed, K-means DML;
//! * **Fig. 7** — the same with rpTrees DML;
//! * **sparse** — beyond the paper: a Fig. 6-style accuracy sweep at
//!   8k–32k codewords on the sparse k-NN spectral path, where the dense
//!   O(m²) affinity is infeasible (32k codewords would need a 4 GiB
//!   matrix).
//!
//! Protocol as in §5.1: 40 000 points, compression 40:1 (1000 codewords),
//! two sites. Run a subset with `cargo bench --bench fig6_fig7_synthetic --
//! fig5|fig6|fig7|sparse`. `DSC_N` scales the point count down for quick
//! runs (it also caps the sparse sweep, which otherwise generates up to
//! 131 072 points).
//!
//! Expected shape vs the paper: every distributed accuracy within ~±0.02
//! of non-distributed; D1 often slightly *above* (the paper's
//! regularization-effect remark); rpTrees a notch below K-means.

use dsc::bench::Table;
use dsc::data::{csvio, gmm};
use dsc::dml::{self, DmlKind, DmlParams};
use dsc::prelude::*;

fn want(filter: &Option<String>, key: &str) -> bool {
    filter.as_deref().map(|f| key.contains(f)).unwrap_or(true)
}

fn main() -> anyhow::Result<()> {
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let n_env: Option<usize> = std::env::var("DSC_N").ok().and_then(|v| v.parse().ok());
    let n = n_env.unwrap_or(40_000);
    let codes = (n / 40).max(16); // the paper's 40:1 compression

    if want(&filter, "fig5") {
        fig5()?;
    }
    if want(&filter, "fig6") {
        figure(DmlKind::KMeans, "fig6", n, codes)?;
    }
    if want(&filter, "fig7") {
        figure(DmlKind::RpTree, "fig7", n, codes)?;
    }
    if want(&filter, "sparse") {
        sparse_sweep(n_env.unwrap_or(usize::MAX))?;
    }
    Ok(())
}

/// Fig. 6-style accuracy sweep at large codebooks (8k–32k codewords), 4:1
/// compression: rpTrees DML (the only transform cheap enough at this many
/// codes) feeding the sparse k-NN central step. The dense path cannot run
/// these sizes — at 32k codewords its affinity alone is 4 GiB.
fn sparse_sweep(n_cap: usize) -> anyhow::Result<()> {
    let mut table = Table::new(
        "Fig. 6 (sparse) — 10-D mixture, knn graph (k=24), rpTrees DML, 2 sites, D3".to_string(),
        &["total_codes", "n", "accuracy", "central (s)", "wire bytes"],
    );
    let mut seen_codes = Vec::new();
    for target in [8_192usize, 16_384, 32_768] {
        let n = (target * 4).min(n_cap.max(1_024));
        let codes = target.min(n / 4);
        if seen_codes.contains(&codes) {
            continue; // DSC_N capped several targets to the same run
        }
        seen_codes.push(codes);
        let ds = gmm::paper_mixture_10d(n, 0.3, 7);
        let cfg = PipelineConfig {
            dml: DmlKind::RpTree,
            total_codes: codes,
            k_clusters: 4,
            bandwidth: Bandwidth::MedianScale(0.5),
            graph: GraphKind::Knn { k: 24 },
            seed: 11,
            ..Default::default()
        };
        let parts = scenario::split(&ds, Scenario::D3, 2, 13);
        let r = run_pipeline(&parts, &cfg)?;
        table.row(&[
            format!("{codes}"),
            format!("{n}"),
            format!("{:.4}", r.accuracy),
            format!("{:.2}", r.central.as_secs_f64()),
            format!("{}", r.net.total_bytes()),
        ]);
    }
    print!("{}", table.render());
    table.save_csv("fig6_sparse")?;
    Ok(())
}

/// Fig. 5: scatter + codewords of the 2-D mixture, sites = {C1+C2, C3+C4}.
fn fig5() -> anyhow::Result<()> {
    let ds = gmm::paper_mixture_2d(4_000, 5);
    csvio::save_dataset(
        std::path::Path::new("bench_out/fig5_points.csv"),
        &ds,
        &["Fig.5 scatter: 2-D 4-component mixture, label = component"],
    )?;

    // Site 1 = components {0,1}, Site 2 = components {2,3} (paper setup)
    let frac = vec![vec![1.0, 1.0, 0.0, 0.0], vec![0.0, 0.0, 1.0, 1.0]];
    let parts = scenario::split_by_fractions(&ds, &frac, 5);
    let mut reps = Dataset::new("fig5_reps", 2, 2);
    for part in &parts {
        let cb = dml::apply(
            &part.data,
            &DmlParams { target_codes: 50, seed: 5, ..Default::default() },
        );
        for c in 0..cb.n_codes() {
            let cw = cb.codeword(c);
            reps.push(&[cw[0], cw[1]], part.site_id as u16);
        }
    }
    csvio::save_dataset(
        std::path::Path::new("bench_out/fig5_codewords.csv"),
        &reps,
        &["Fig.5 representative points, label = site"],
    )?;
    println!("fig5: wrote bench_out/fig5_points.csv and bench_out/fig5_codewords.csv");
    Ok(())
}

/// Figs. 6/7: accuracy across ρ × scenario for one DML.
fn figure(dmlk: DmlKind, name: &str, n: usize, codes: usize) -> anyhow::Result<()> {
    let mut table = Table::new(
        format!(
            "{} — 10-D mixture accuracy, {dmlk} DML, n={n}, {codes} codewords, 2 sites",
            if dmlk == DmlKind::KMeans { "Fig. 6" } else { "Fig. 7" }
        ),
        &["rho", "non-distributed", "D1", "D2", "D3"],
    );
    for rho in [0.1, 0.3, 0.6] {
        let ds = gmm::paper_mixture_10d(n, rho, 7);
        let cfg = PipelineConfig {
            dml: dmlk,
            total_codes: codes,
            k_clusters: 4,
            bandwidth: Bandwidth::MedianScale(0.5),
            seed: 11,
            ..Default::default()
        };
        let base = run_pipeline(
            &[SitePart {
                site_id: 0,
                data: ds.clone(),
                global_idx: (0..ds.len() as u32).collect(),
            }],
            &cfg,
        )?;
        let mut cells = vec![format!("{rho}"), format!("{:.4}", base.accuracy)];
        for sc in [Scenario::D1, Scenario::D2, Scenario::D3] {
            let parts = scenario::split(&ds, sc, 2, 13);
            let r = run_pipeline(&parts, &cfg)?;
            cells.push(format!("{:.4}", r.accuracy));
        }
        table.row(&cells);
    }
    print!("{}", table.render());
    table.save_csv(name)?;
    Ok(())
}
