//! Regenerates the paper's synthetic-data evaluation:
//!
//! * **Fig. 5** — the 2-D 4-component mixture scatter with representative
//!   points from 2 sites (emitted as CSVs for plotting);
//! * **Fig. 6** — clustering accuracy on the 10-D mixture, ρ ∈
//!   {0.1, 0.3, 0.6}, scenarios D1/D2/D3 vs non-distributed, K-means DML;
//! * **Fig. 7** — the same with rpTrees DML.
//!
//! Protocol as in §5.1: 40 000 points, compression 40:1 (1000 codewords),
//! two sites. Run a subset with `cargo bench --bench fig6_fig7_synthetic --
//! fig5|fig6|fig7`. `DSC_N` scales the point count down for quick runs.
//!
//! Expected shape vs the paper: every distributed accuracy within ~±0.02
//! of non-distributed; D1 often slightly *above* (the paper's
//! regularization-effect remark); rpTrees a notch below K-means.

use dsc::bench::Table;
use dsc::data::{csvio, gmm};
use dsc::dml::{self, DmlKind, DmlParams};
use dsc::prelude::*;

fn want(filter: &Option<String>, key: &str) -> bool {
    filter.as_deref().map(|f| key.contains(f)).unwrap_or(true)
}

fn main() -> anyhow::Result<()> {
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
    let n: usize = std::env::var("DSC_N").ok().and_then(|v| v.parse().ok()).unwrap_or(40_000);
    let codes = (n / 40).max(16); // the paper's 40:1 compression

    if want(&filter, "fig5") {
        fig5()?;
    }
    if want(&filter, "fig6") {
        figure(DmlKind::KMeans, "fig6", n, codes)?;
    }
    if want(&filter, "fig7") {
        figure(DmlKind::RpTree, "fig7", n, codes)?;
    }
    Ok(())
}

/// Fig. 5: scatter + codewords of the 2-D mixture, sites = {C1+C2, C3+C4}.
fn fig5() -> anyhow::Result<()> {
    let ds = gmm::paper_mixture_2d(4_000, 5);
    csvio::save_dataset(
        std::path::Path::new("bench_out/fig5_points.csv"),
        &ds,
        &["Fig.5 scatter: 2-D 4-component mixture, label = component"],
    )?;

    // Site 1 = components {0,1}, Site 2 = components {2,3} (paper setup)
    let frac = vec![vec![1.0, 1.0, 0.0, 0.0], vec![0.0, 0.0, 1.0, 1.0]];
    let parts = scenario::split_by_fractions(&ds, &frac, 5);
    let mut reps = Dataset::new("fig5_reps", 2, 2);
    for part in &parts {
        let cb = dml::apply(
            &part.data,
            &DmlParams { target_codes: 50, seed: 5, ..Default::default() },
        );
        for c in 0..cb.n_codes() {
            let cw = cb.codeword(c);
            reps.push(&[cw[0], cw[1]], part.site_id as u16);
        }
    }
    csvio::save_dataset(
        std::path::Path::new("bench_out/fig5_codewords.csv"),
        &reps,
        &["Fig.5 representative points, label = site"],
    )?;
    println!("fig5: wrote bench_out/fig5_points.csv and bench_out/fig5_codewords.csv");
    Ok(())
}

/// Figs. 6/7: accuracy across ρ × scenario for one DML.
fn figure(dmlk: DmlKind, name: &str, n: usize, codes: usize) -> anyhow::Result<()> {
    let mut table = Table::new(
        format!(
            "{} — 10-D mixture accuracy, {dmlk} DML, n={n}, {codes} codewords, 2 sites",
            if dmlk == DmlKind::KMeans { "Fig. 6" } else { "Fig. 7" }
        ),
        &["rho", "non-distributed", "D1", "D2", "D3"],
    );
    for rho in [0.1, 0.3, 0.6] {
        let ds = gmm::paper_mixture_10d(n, rho, 7);
        let cfg = PipelineConfig {
            dml: dmlk,
            total_codes: codes,
            k_clusters: 4,
            bandwidth: Bandwidth::MedianScale(0.5),
            seed: 11,
            ..Default::default()
        };
        let base = run_pipeline(
            &[SitePart {
                site_id: 0,
                data: ds.clone(),
                global_idx: (0..ds.len() as u32).collect(),
            }],
            &cfg,
        )?;
        let mut cells = vec![format!("{rho}"), format!("{:.4}", base.accuracy)];
        for sc in [Scenario::D1, Scenario::D2, Scenario::D3] {
            let parts = scenario::split(&ds, sc, 2, 13);
            let r = run_pipeline(&parts, &cfg)?;
            cells.push(format!("{:.4}", r.accuracy));
        }
        table.row(&cells);
    }
    print!("{}", table.render());
    table.save_csv(name)?;
    Ok(())
}
