//! Job-server load bench: the repo's recorded BENCH trajectory
//! (`bench_out/BENCH_jobserver.json`).
//!
//! Runs the canonical skewed 3-tenant mix (12 jobs at weight 1, 6 at
//! weight 2, 3 at weight 4 — `LoadMix::skewed_three`) through the
//! deterministic channel load generator twice: once under the legacy
//! global FIFO and once under DRR weighted fair queueing, then records
//! throughput, per-tenant sojourn percentiles, pipelining utilization and
//! the Jain fairness index for both. Virtual time makes every number a
//! pure function of the mix — the bench re-runs the DRR leg and fails if
//! the two reports differ by a single bit, and it fails loudly when
//! fairness or throughput regresses past the sanity floors below. A
//! fourth, event-sourced leg re-runs DRR with the run journal on
//! (`[leader] journal_path`): its report must be bit-identical too —
//! journaling may only spend wall clock, never virtual time — and the
//! wall-clock delta is recorded alongside the deterministic numbers.
//!
//! Two hostile legs complete the trajectory: the **chaos** leg runs the
//! six-job `run_chaos_mix` plan (straggler, mid-backlog site outage,
//! staged leader crash-and-recover) against its fault-free twin — only
//! the two faulted runs may fail and every survivor must match the twin
//! bit for bit; the **adversarial** leg runs `run_adversarial_mix` with
//! token-bucket admission on — the flood is clipped at the burst with
//! typed `RateLimited` refusals and the paying tenants' p99 stays within
//! 3× of the flooder-free twin. Both re-check their floors here so a
//! regression cannot silently land in the recorded trajectory.
//!
//! `cargo bench --bench jobserver_load` — add `-- tcp` to also push the
//! same mix through a real loopback TCP job server (wall-clock numbers,
//! printed but deliberately kept out of the deterministic JSON).
//! `DSC_BENCH_OUT` overrides the output directory (default `bench_out/`).

use std::time::Instant;

use anyhow::{bail, Result};
use dsc::bench::Table;
use dsc::coordinator::loadgen::{
    run_adversarial_mix, run_channel_load, run_channel_load_journaled, run_chaos_mix,
    run_chaos_twin, run_tcp_load, AdversarialMix, AdversarialReport, ChaosReport, ChaosRun,
    LoadMix, LoadReport,
};

/// Sanity floors: a scheduling or harness regression trips these before
/// it can silently land in the recorded trajectory.
fn check_floors(fifo: &LoadReport, drr: &LoadReport) -> Result<()> {
    for (name, r) in [("fifo", fifo), ("drr", drr)] {
        if r.completed != r.jobs as u64 || r.rejected != 0 {
            bail!(
                "{name}: {} of {} jobs completed, {} rejected — the load mix must drain fully",
                r.completed,
                r.jobs,
                r.rejected
            );
        }
        if r.utilization < 0.999 {
            bail!("{name}: utilization {} — the service slot idled", r.utilization);
        }
        let ideal = 1e9 / (r.makespan_ns as f64 / r.jobs as f64);
        if r.throughput_jobs_per_sec < 0.9 * ideal {
            bail!(
                "{name}: throughput {} jobs/s below sanity floor {}",
                r.throughput_jobs_per_sec,
                0.9 * ideal
            );
        }
    }
    if drr.fairness < 0.95 {
        bail!("drr: fairness index {} below the 0.95 floor", drr.fairness);
    }
    if drr.fairness < fifo.fairness + 0.1 {
        bail!(
            "fairness gap collapsed: drr {} vs fifo {} — DRR must beat FIFO by ≥ 0.1 \
             on the skewed mix",
            drr.fairness,
            fifo.fairness
        );
    }
    // the high-weight light tenant must actually see better latency
    let (f, d) = (&fifo.per_client[2], &drr.per_client[2]);
    if d.mean_ns >= f.mean_ns {
        bail!(
            "weight-4 tenant mean sojourn under drr ({} ns) is not below fifo ({} ns)",
            d.mean_ns,
            f.mean_ns
        );
    }
    Ok(())
}

/// Floors for the chaos leg: the fault plan may cost exactly the two
/// faulted runs, every survivor must match the fault-free twin bit for
/// bit, and the recovered journal must have kept recording.
fn check_chaos(chaos: &ChaosReport, twin: &ChaosReport) -> Result<()> {
    if (twin.completed, twin.failed, twin.rejected) != (6, 0, 0) {
        bail!("chaos twin: {}/{} completed/failed — the plan itself must be clean",
            twin.completed, twin.failed);
    }
    if (chaos.completed, chaos.failed, chaos.rejected) != (4, 2, 0) {
        bail!(
            "chaos: {} completed, {} failed, {} rejected — exactly the two faulted runs may fail",
            chaos.completed, chaos.failed, chaos.rejected
        );
    }
    for (i, r) in chaos.results.iter().enumerate() {
        if matches!(r, ChaosRun::Done { .. }) && r != &twin.results[i] {
            bail!("chaos: survivor run {} diverged from its fault-free twin", i + 1);
        }
    }
    for (site, s) in chaos.sessions.iter().enumerate() {
        if s.0 != 4 {
            bail!("chaos: site {site} served {} runs, expected all 4 survivors", s.0);
        }
    }
    if chaos.journal_records <= 13 {
        bail!(
            "chaos: journal holds {} records — recovery must resume event-sourcing \
             past the 13-record crash prefix",
            chaos.journal_records
        );
    }
    Ok(())
}

/// Floors for the adversarial leg: the flood clipped at the burst with
/// typed rate-limit refusals, and the paying p99 within 3× of the
/// flooder-free twin.
fn check_adversarial(flood: &AdversarialReport, quiet: &AdversarialReport) -> Result<()> {
    if flood.flooder_accepted != 8 || flood.flooder_rejects.len() != 12 {
        bail!(
            "adversarial: {} admitted / {} refused — the burst must clip the flood at 8/12",
            flood.flooder_accepted,
            flood.flooder_rejects.len()
        );
    }
    for &(code, detail) in &flood.flooder_rejects {
        if code != dsc::net::RejectCode::RateLimited || detail == 0 {
            bail!("adversarial: refusal {code:?}/{detail} — every reject must be a typed \
                   RateLimited with a positive wait");
        }
    }
    if (flood.completed, flood.rejected) != (20, 12) || (quiet.completed, quiet.rejected) != (12, 0)
    {
        bail!("adversarial: completed/rejected {}/{} flooded, {}/{} quiet",
            flood.completed, flood.rejected, quiet.completed, quiet.rejected);
    }
    for (p, q) in flood.paying.iter().zip(&quiet.paying) {
        if p.p99_ns > 3 * q.p99_ns {
            bail!(
                "adversarial: paying client {} p99 {} ns vs {} ns quiet — the flood must \
                 cost at most 3×",
                p.client, p.p99_ns, q.p99_ns
            );
        }
    }
    if quiet.fairness < 0.95 {
        bail!("adversarial: quiet fairness {} below the 0.95 floor", quiet.fairness);
    }
    Ok(())
}

fn indent(json: &str) -> String {
    json.replace('\n', "\n  ")
}

fn main() -> Result<()> {
    let tcp = std::env::args().skip(1).any(|a| a == "tcp");

    let fifo = run_channel_load(&LoadMix::skewed_three(false))?;
    let t_off = Instant::now();
    let drr = run_channel_load(&LoadMix::skewed_three(true))?;
    let wall_off = t_off.elapsed();
    // same mix ⇒ same numbers, bit for bit — determinism is part of the
    // bench contract, not just a test
    let drr_again = run_channel_load(&LoadMix::skewed_three(true))?;
    if drr_again != drr {
        bail!("nondeterministic load report: two identical DRR runs disagreed");
    }
    check_floors(&fifo, &drr)?;

    // The journaling arm: event-source the identical DRR leg. The report
    // is pure virtual time, so this is the regression floor proving the
    // journal stays off the measured path — a single moved bit fails the
    // bench; only the wall clock is allowed to pay, and the delta is
    // recorded below (real time, so it varies run to run by design).
    let jpath = std::env::temp_dir()
        .join(format!("dsc-bench-jobserver-{}.journal", std::process::id()));
    let _ = std::fs::remove_file(&jpath);
    let t_on = Instant::now();
    let drr_journaled = run_channel_load_journaled(&LoadMix::skewed_three(true), &jpath, false)?;
    let wall_on = t_on.elapsed();
    let journal_bytes = std::fs::metadata(&jpath).map(|m| m.len()).unwrap_or(0);
    let _ = std::fs::remove_file(&jpath);
    if drr_journaled != drr {
        bail!("journaling moved the deterministic report: journaled DRR leg disagreed");
    }

    // The chaos leg: straggler + mid-backlog site outage + staged leader
    // crash-and-recover over a six-job DRR plan, held to its fault-free
    // twin (rust/tests/chaos_mix.rs is the full suite; the bench records
    // the outcome counts and re-checks the floors).
    let cpath = std::env::temp_dir()
        .join(format!("dsc-bench-chaos-{}.journal", std::process::id()));
    let _ = std::fs::remove_file(&cpath);
    let chaos_twin = run_chaos_twin()?;
    let chaos = run_chaos_mix(&cpath)?;
    let _ = std::fs::remove_file(&cpath);
    check_chaos(&chaos, &chaos_twin)?;

    // The adversarial leg: a 20-submit flood against two paying tenants
    // with token-bucket admission on, held to the flooder-free twin.
    let adv_quiet = run_adversarial_mix(&AdversarialMix::canonical(false))?;
    let adv_flood = run_adversarial_mix(&AdversarialMix::canonical(true))?;
    check_adversarial(&adv_flood, &adv_quiet)?;

    let mut table = Table::new(
        "Job-server load: skewed 3-tenant mix (12×w1 / 6×w2 / 3×w4), virtual time",
        &["queue", "fairness", "jobs/s", "p95 w1", "p95 w2", "p95 w4"],
    );
    for (name, r) in [("fifo", &fifo), ("drr", &drr)] {
        table.row(&[
            name.into(),
            format!("{:.4}", r.fairness),
            format!("{:.1}", r.throughput_jobs_per_sec),
            format!("{:.1}ms", r.per_client[0].p95_ns as f64 / 1e6),
            format!("{:.1}ms", r.per_client[1].p95_ns as f64 / 1e6),
            format!("{:.1}ms", r.per_client[2].p95_ns as f64 / 1e6),
        ]);
    }
    print!("{}", table.render());
    println!(
        "journal arm: report bit-identical; wall {:.1}ms off vs {:.1}ms on \
         ({:+.1}%, {} journal bytes — wall clock, not part of the deterministic record)",
        wall_off.as_secs_f64() * 1e3,
        wall_on.as_secs_f64() * 1e3,
        (wall_on.as_secs_f64() / wall_off.as_secs_f64().max(1e-9) - 1.0) * 100.0,
        journal_bytes
    );
    println!(
        "chaos leg: {}/6 completed under straggler+outage+crash (twin {}/6), \
         survivors bit-identical to the twin, {} journal records after recovery",
        chaos.completed, chaos_twin.completed, chaos.journal_records
    );
    println!(
        "adversarial leg: flood clipped {}→{} admitted / {} RateLimited; \
         paying p99 {:.1}ms/{:.1}ms flooded vs {:.1}ms/{:.1}ms quiet (≤3× floor)",
        20,
        adv_flood.flooder_accepted,
        adv_flood.flooder_rejects.len(),
        adv_flood.paying[0].p99_ns as f64 / 1e6,
        adv_flood.paying[1].p99_ns as f64 / 1e6,
        adv_quiet.paying[0].p99_ns as f64 / 1e6,
        adv_quiet.paying[1].p99_ns as f64 / 1e6,
    );

    let out_dir = std::env::var("DSC_BENCH_OUT").unwrap_or_else(|_| "bench_out".into());
    std::fs::create_dir_all(&out_dir)?;
    let path = std::path::Path::new(&out_dir).join("BENCH_jobserver.json");
    // The chaos object records only virtual-time-deterministic outcomes:
    // whether the severed pop's work order beat the site-down to the
    // sites is a real-time race, so journal record and per-site DML
    // counts stay out of the recorded trajectory.
    let survivors_match = chaos
        .results
        .iter()
        .enumerate()
        .all(|(i, r)| !matches!(r, ChaosRun::Done { .. }) || r == &chaos_twin.results[i]);
    let body = format!(
        "{{\n  \"bench\": \"jobserver_load\",\n  \"mix\": \"skewed_three 12xw1/6xw2/3xw4\",\n  \
         \"fifo\": {},\n  \"drr\": {},\n  \"journal\": {{\n    \
         \"report_identical_to_drr\": true,\n    \"journal_bytes\": {journal_bytes},\n    \
         \"wall_ms_off\": {:.3},\n    \"wall_ms_on\": {:.3}\n  }},\n  \
         \"chaos\": {{\n    \"completed\": {},\n    \"failed\": {},\n    \"rejected\": {},\n    \
         \"twin_completed\": {},\n    \"survivors_match_twin\": {},\n    \
         \"runs_served_per_site\": [{}]\n  }},\n  \
         \"adversarial\": {{\n    \"quiet\": {},\n    \"flood\": {}\n  }}\n}}\n",
        indent(&fifo.to_json()),
        indent(&drr.to_json()),
        wall_off.as_secs_f64() * 1e3,
        wall_on.as_secs_f64() * 1e3,
        chaos.completed,
        chaos.failed,
        chaos.rejected,
        chaos_twin.completed,
        survivors_match,
        chaos.sessions.iter().map(|s| s.0.to_string()).collect::<Vec<_>>().join(", "),
        indent(&indent(&adv_quiet.to_json())),
        indent(&indent(&adv_flood.to_json())),
    );
    std::fs::write(&path, body)?;
    println!("\nwrote {}", path.display());

    if tcp {
        let report = run_tcp_load(&LoadMix::skewed_three(true))?;
        println!(
            "tcp twin: {}/{} jobs in {:.3}s ({:.1} jobs/s, wall clock — not recorded)",
            report.completed,
            report.jobs,
            report.wall.as_secs_f64(),
            report.throughput_jobs_per_sec
        );
    }
    Ok(())
}
