//! Job-server load bench: the repo's recorded BENCH trajectory
//! (`bench_out/BENCH_jobserver.json`).
//!
//! Runs the canonical skewed 3-tenant mix (12 jobs at weight 1, 6 at
//! weight 2, 3 at weight 4 — `LoadMix::skewed_three`) through the
//! deterministic channel load generator twice: once under the legacy
//! global FIFO and once under DRR weighted fair queueing, then records
//! throughput, per-tenant sojourn percentiles, pipelining utilization and
//! the Jain fairness index for both. Virtual time makes every number a
//! pure function of the mix — the bench re-runs the DRR leg and fails if
//! the two reports differ by a single bit, and it fails loudly when
//! fairness or throughput regresses past the sanity floors below. A
//! fourth, event-sourced leg re-runs DRR with the run journal on
//! (`[leader] journal_path`): its report must be bit-identical too —
//! journaling may only spend wall clock, never virtual time — and the
//! wall-clock delta is recorded alongside the deterministic numbers.
//!
//! `cargo bench --bench jobserver_load` — add `-- tcp` to also push the
//! same mix through a real loopback TCP job server (wall-clock numbers,
//! printed but deliberately kept out of the deterministic JSON).
//! `DSC_BENCH_OUT` overrides the output directory (default `bench_out/`).

use std::time::Instant;

use anyhow::{bail, Result};
use dsc::bench::Table;
use dsc::coordinator::loadgen::{
    run_channel_load, run_channel_load_journaled, run_tcp_load, LoadMix, LoadReport,
};

/// Sanity floors: a scheduling or harness regression trips these before
/// it can silently land in the recorded trajectory.
fn check_floors(fifo: &LoadReport, drr: &LoadReport) -> Result<()> {
    for (name, r) in [("fifo", fifo), ("drr", drr)] {
        if r.completed != r.jobs as u64 || r.rejected != 0 {
            bail!(
                "{name}: {} of {} jobs completed, {} rejected — the load mix must drain fully",
                r.completed,
                r.jobs,
                r.rejected
            );
        }
        if r.utilization < 0.999 {
            bail!("{name}: utilization {} — the service slot idled", r.utilization);
        }
        let ideal = 1e9 / (r.makespan_ns as f64 / r.jobs as f64);
        if r.throughput_jobs_per_sec < 0.9 * ideal {
            bail!(
                "{name}: throughput {} jobs/s below sanity floor {}",
                r.throughput_jobs_per_sec,
                0.9 * ideal
            );
        }
    }
    if drr.fairness < 0.95 {
        bail!("drr: fairness index {} below the 0.95 floor", drr.fairness);
    }
    if drr.fairness < fifo.fairness + 0.1 {
        bail!(
            "fairness gap collapsed: drr {} vs fifo {} — DRR must beat FIFO by ≥ 0.1 \
             on the skewed mix",
            drr.fairness,
            fifo.fairness
        );
    }
    // the high-weight light tenant must actually see better latency
    let (f, d) = (&fifo.per_client[2], &drr.per_client[2]);
    if d.mean_ns >= f.mean_ns {
        bail!(
            "weight-4 tenant mean sojourn under drr ({} ns) is not below fifo ({} ns)",
            d.mean_ns,
            f.mean_ns
        );
    }
    Ok(())
}

fn indent(json: &str) -> String {
    json.replace('\n', "\n  ")
}

fn main() -> Result<()> {
    let tcp = std::env::args().skip(1).any(|a| a == "tcp");

    let fifo = run_channel_load(&LoadMix::skewed_three(false))?;
    let t_off = Instant::now();
    let drr = run_channel_load(&LoadMix::skewed_three(true))?;
    let wall_off = t_off.elapsed();
    // same mix ⇒ same numbers, bit for bit — determinism is part of the
    // bench contract, not just a test
    let drr_again = run_channel_load(&LoadMix::skewed_three(true))?;
    if drr_again != drr {
        bail!("nondeterministic load report: two identical DRR runs disagreed");
    }
    check_floors(&fifo, &drr)?;

    // The journaling arm: event-source the identical DRR leg. The report
    // is pure virtual time, so this is the regression floor proving the
    // journal stays off the measured path — a single moved bit fails the
    // bench; only the wall clock is allowed to pay, and the delta is
    // recorded below (real time, so it varies run to run by design).
    let jpath = std::env::temp_dir()
        .join(format!("dsc-bench-jobserver-{}.journal", std::process::id()));
    let _ = std::fs::remove_file(&jpath);
    let t_on = Instant::now();
    let drr_journaled = run_channel_load_journaled(&LoadMix::skewed_three(true), &jpath, false)?;
    let wall_on = t_on.elapsed();
    let journal_bytes = std::fs::metadata(&jpath).map(|m| m.len()).unwrap_or(0);
    let _ = std::fs::remove_file(&jpath);
    if drr_journaled != drr {
        bail!("journaling moved the deterministic report: journaled DRR leg disagreed");
    }

    let mut table = Table::new(
        "Job-server load: skewed 3-tenant mix (12×w1 / 6×w2 / 3×w4), virtual time",
        &["queue", "fairness", "jobs/s", "p95 w1", "p95 w2", "p95 w4"],
    );
    for (name, r) in [("fifo", &fifo), ("drr", &drr)] {
        table.row(&[
            name.into(),
            format!("{:.4}", r.fairness),
            format!("{:.1}", r.throughput_jobs_per_sec),
            format!("{:.1}ms", r.per_client[0].p95_ns as f64 / 1e6),
            format!("{:.1}ms", r.per_client[1].p95_ns as f64 / 1e6),
            format!("{:.1}ms", r.per_client[2].p95_ns as f64 / 1e6),
        ]);
    }
    print!("{}", table.render());
    println!(
        "journal arm: report bit-identical; wall {:.1}ms off vs {:.1}ms on \
         ({:+.1}%, {} journal bytes — wall clock, not part of the deterministic record)",
        wall_off.as_secs_f64() * 1e3,
        wall_on.as_secs_f64() * 1e3,
        (wall_on.as_secs_f64() / wall_off.as_secs_f64().max(1e-9) - 1.0) * 100.0,
        journal_bytes
    );

    let out_dir = std::env::var("DSC_BENCH_OUT").unwrap_or_else(|_| "bench_out".into());
    std::fs::create_dir_all(&out_dir)?;
    let path = std::path::Path::new(&out_dir).join("BENCH_jobserver.json");
    let body = format!(
        "{{\n  \"bench\": \"jobserver_load\",\n  \"mix\": \"skewed_three 12xw1/6xw2/3xw4\",\n  \
         \"fifo\": {},\n  \"drr\": {},\n  \"journal\": {{\n    \
         \"report_identical_to_drr\": true,\n    \"journal_bytes\": {journal_bytes},\n    \
         \"wall_ms_off\": {:.3},\n    \"wall_ms_on\": {:.3}\n  }}\n}}\n",
        indent(&fifo.to_json()),
        indent(&drr.to_json()),
        wall_off.as_secs_f64() * 1e3,
        wall_on.as_secs_f64() * 1e3,
    );
    std::fs::write(&path, body)?;
    println!("\nwrote {}", path.display());

    if tcp {
        let report = run_tcp_load(&LoadMix::skewed_three(true))?;
        println!(
            "tcp twin: {}/{} jobs in {:.3}s ({:.1} jobs/s, wall clock — not recorded)",
            report.completed,
            report.jobs,
            report.wall.as_secs_f64(),
            report.throughput_jobs_per_sec
        );
    }
    Ok(())
}
