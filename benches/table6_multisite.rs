//! Regenerates Table 6 (+ Table 5's configurations): HEPMASS with 2, 3 and
//! 4 distributed sites, both DMLs, accuracy and elapsed time per scenario.
//!
//! Expected shape vs the paper: accuracy flat in the number of sites;
//! elapsed time decreasing in sites with diminishing returns (the central
//! spectral step doesn't shrink), more pronounced for rpTrees whose local
//! phase is already cheap.
//!
//! `DSC_N` scales the proxy size (default 40 000).

use dsc::bench::Table;
use dsc::data::uci_proxy;
use dsc::dml::DmlKind;
use dsc::prelude::*;

fn main() -> anyhow::Result<()> {
    let spec = uci_proxy::by_name("hepmass").unwrap();
    let n: usize = std::env::var("DSC_N").ok().and_then(|v| v.parse().ok()).unwrap_or(40_000);
    let ds = spec.generate(n, 51);

    let mut table = Table::new(
        format!("Table 6 — HEPMASS proxy (n={n}), accuracy / elapsed s"),
        &["dml", "sites", "non-dist", "D1", "D2", "D3"],
    );

    for dml in [DmlKind::KMeans, DmlKind::RpTree] {
        let cfg = PipelineConfig {
            dml,
            total_codes: spec.target_codewords().min(n / 8),
            k_clusters: 2,
            bandwidth: Bandwidth::MedianScale(0.75),
            seed: 53,
            ..Default::default()
        };
        let base = run_pipeline(
            &[SitePart {
                site_id: 0,
                data: ds.clone(),
                global_idx: (0..ds.len() as u32).collect(),
            }],
            &cfg,
        )?;
        let base_cell =
            format!("{:.4} / {:.2}", base.accuracy, base.elapsed_model.as_secs_f64());

        for sites in [2usize, 3, 4] {
            let mut cells =
                vec![format!("{dml}_{sites}"), sites.to_string(), base_cell.clone()];
            for sc in [Scenario::D1, Scenario::D2, Scenario::D3] {
                let parts = scenario::split(&ds, sc, sites, 59);
                let r = run_pipeline(&parts, &cfg)?;
                cells.push(format!(
                    "{:.4} / {:.2}",
                    r.accuracy,
                    r.elapsed_model.as_secs_f64()
                ));
            }
            table.row(&cells);
            eprintln!("  done {dml} × {sites} sites");
        }
    }
    print!("{}", table.render());
    table.save_csv("table6")?;
    Ok(())
}
